//! Decode engines: native fp32, LUT bit-plane, and PJRT (AOT artifact).
//!
//! An [`Engine`] is one worker's decode backend. Its entry point is
//! [`Engine::serve`]: run the persistent iteration-level scheduler
//! ([`super::scheduler`]) over a [`SubmitQueue`] until the queue closes,
//! streaming `GenEvent`s per request. The engine's contribution is the
//! [`Stepper`]: how one sweep (every active session advancing one
//! token) is *executed*:
//!
//! * [`NativeStepper`] steps each session independently — dense matvecs
//!   share nothing across sessions, so the per-session path is kept
//!   unchanged (its KV still lives in the shared arena);
//! * [`BatchedLutStep`] fuses the sweep: one multi-LUT build per linear,
//!   per-layer **batched** linears via [`crate::lut::lut_gemm`] (each
//!   row's packed plane words are gathered once for all active sessions),
//!   and a **fused attention phase**: every session's KV is a slot of
//!   the model's pooled [`KvArena`], sessions are grouped by decode
//!   position, and each layer runs the score/softmax/AV phase as a
//!   single multi-session pass per (layer, kv-head) —
//!   [`crate::tensor::strip_dots`] / [`crate::tensor::strip_axpys`]
//!   walk the whole group together in one position-major sweep instead
//!   of B separate strip walks. Since the arena is *paged*, the sweep
//!   runs page run by page run: each lane contributes its own page for
//!   the run (cache-hit sessions may point at pages shared with other
//!   sessions through the prefix cache), scores are scattered into a
//!   lane-major `(t+1)`-wide buffer, and AV accumulates across runs in
//!   ascending position order — the exact accumulation order of the
//!   monolithic sweep, so paging is invisible to tokens. The phase
//!   dispatches on the arena's [`KvFormat`]: packed bit-plane strips go
//!   through the fused-dequant kernels
//!   ([`crate::tensor::strip_dots_packed`] /
//!   [`crate::tensor::strip_axpys_packed`]) so quantized KV is consumed
//!   in place — quantization itself happens once, at store time in the
//!   session step. Together with grouped-query attention (KV caches are
//!   `kv_dim`-wide, `n_heads / n_kv_heads` smaller than `d_model`) this
//!   amortizes both the weight fetch and the KV bandwidth across the
//!   batch — the decode-side analogue of ABQ-LLM's batched
//!   binary-matrix kernels.
//! * [`PjrtStepper`] threads each session's KV-cache literals through
//!   the AOT `decode_step` executable, one `run` per session per sweep
//!   (loaded/compiled **once** per serve loop, not per request).
//!
//! Because a sweep is the unit of execution for every backend, sessions
//! with different prompts, lengths, and arrival times batch freely —
//! continuous batching falls out of the `Stepper` contract rather than
//! being reimplemented per engine.
//!
//! The legacy batch-synchronous [`Engine::generate_batch`] survives as
//! a thin wrapper: it pre-fills a queue, runs the same scheduler with
//! `max_batch = batch.len()`, and folds each event stream into a
//! [`Response`] — so its temp=0 output is token-identical to streaming.

use super::batcher::{Pending, SubmitQueue};
use super::kv::{KvArena, KvFormat, KvHandle, KvView};
use super::metrics::Metrics;
use super::prefix::{register_reclaimer, PrefixCache};
use super::scheduler::{run_scheduler, ChunkPolicy, Session, Stepper};
use super::{CancelHandle, GenRequest, Request, Response, SamplingParams};
use crate::lut::{lut_gemm, LutScratch};
use crate::model::{rmsnorm, silu, softmax, DecodeState, Model, Rope};
use crate::quant::packing::BitPlanePacked;
use crate::runtime::{self, LoadedExecutable, Runtime};
use crate::tensor::{
    matvec, strip_axpys, strip_axpys_packed, strip_dots, strip_dots_packed, PackedStrip,
};
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::Instant;

/// A model whose block linears are *packed bit-planes* — the paper's
/// deployment format. Non-linear parts (norms, embeddings, lm_head) stay
/// dense.
#[derive(Clone)]
pub struct LutModel {
    pub base: Arc<Model>,
    /// "l{layer}.{name}" → packed record for all 7 block linears.
    pub packed: Arc<HashMap<String, BitPlanePacked>>,
}

impl LutModel {
    pub fn new(base: Arc<Model>, packed: HashMap<String, BitPlanePacked>) -> Result<Self> {
        for l in 0..base.cfg.n_layers {
            for name in crate::model::BLOCK_LINEARS {
                anyhow::ensure!(
                    packed.contains_key(&format!("l{l}.{name}")),
                    "missing packed record l{l}.{name}"
                );
            }
        }
        Ok(Self { base, packed: Arc::new(packed) })
    }
}

/// Which decode path a worker runs.
#[derive(Clone)]
pub enum EngineKind {
    /// dense f32 matvecs over (dequantized or fp) weights
    Native(Arc<Model>),
    /// batched LUT-GEMM over packed bit-planes
    Lut(LutModel),
    /// PJRT execution of the AOT `decode_step.hlo.txt`
    Pjrt { model: Arc<Model>, artifact: PathBuf, cache_len: usize },
}

/// A decode engine (one per worker thread).
pub struct Engine {
    kind: EngineKind,
    runtime: Option<Runtime>,
    lut_step: Option<BatchedLutStep>,
    metrics: Option<Metrics>,
    prefix_cache: Option<Arc<PrefixCache>>,
    prefill_chunk: usize,
    sweep_budget: Option<usize>,
}

impl Engine {
    pub fn new(kind: EngineKind) -> Result<Self> {
        let runtime = match &kind {
            EngineKind::Pjrt { .. } => Some(Runtime::cpu()?),
            _ => None,
        };
        let lut_step = match &kind {
            EngineKind::Lut(lm) => Some(BatchedLutStep::new(lm.clone())),
            _ => None,
        };
        Ok(Self {
            kind,
            runtime,
            lut_step,
            metrics: None,
            prefix_cache: None,
            prefill_chunk: 1,
            sweep_budget: None,
        })
    }

    /// Configure Sarathi-style chunked prefill (`serve --prefill-chunk`
    /// / `--sweep-token-budget`): prefilling sessions consume up to
    /// `chunk` prompt tokens per sweep through the multi-token step
    /// path, under a per-sweep token budget that decode claims first
    /// (see `serving` module docs, "Chunked prefill"). `None` budget
    /// defaults to `max_batch × chunk` at serve time. The default
    /// (`chunk = 1`, no budget) is exactly the legacy
    /// one-token-per-sweep prefill.
    pub fn configure_prefill(&mut self, chunk: usize, sweep_token_budget: Option<usize>) {
        self.prefill_chunk = chunk.max(1);
        self.sweep_budget = sweep_token_budget;
    }

    /// Build and wire a radix prefix cache over this engine's KV arena
    /// (`serve --prefix-cache`): admission borrows cached prompt-prefix
    /// pages read-only, prefill completion publishes them, and the
    /// cache's LRU evictor is registered as the arena's under-pressure
    /// reclaimer. Idempotent; a no-op for the PJRT path (its cache
    /// travels as literals, not arena pages).
    pub fn enable_prefix_cache(&mut self) {
        if self.prefix_cache.is_some() {
            return;
        }
        if let Some(arena) = self.arena() {
            let cache = Arc::new(PrefixCache::new(arena));
            register_reclaimer(cache.arena(), &cache);
            self.prefix_cache = Some(cache);
        }
    }

    /// The prefix cache wired by [`Engine::enable_prefix_cache`], if any
    /// (for stats readout; sessions reach it through the scheduler).
    pub fn prefix_cache(&self) -> Option<&Arc<PrefixCache>> {
        self.prefix_cache.as_ref()
    }

    pub fn kind_name(&self) -> &'static str {
        match self.kind {
            EngineKind::Native(_) => "native",
            EngineKind::Lut(_) => "lut",
            EngineKind::Pjrt { .. } => "pjrt",
        }
    }

    /// Give the engine a metrics handle so the scheduler records TTFT,
    /// inter-token latency, sweep occupancy, and arena snapshots (the
    /// router wires this up for its workers).
    pub fn attach_metrics(&mut self, metrics: Metrics) {
        self.metrics = Some(metrics);
    }

    /// The pooled KV arena this engine's sessions draw slots from (none
    /// for the PJRT path, which threads its cache through literals).
    fn arena(&self) -> Option<Arc<KvArena>> {
        match &self.kind {
            EngineKind::Native(model) => Some(model.kv_arena()),
            EngineKind::Lut(lm) => Some(lm.base.kv_arena()),
            EngineKind::Pjrt { .. } => None,
        }
    }

    /// Run the persistent iteration-level scheduling loop over `queue`
    /// until it is closed and drained: admit queued requests into free
    /// slots (≤ `max_batch`) at every sweep boundary, advance all
    /// active sessions one token per sweep, stream `GenEvent`s, and
    /// retire finished / cancelled sessions immediately so their arena
    /// slots are reused. On a stepper error every in-flight request
    /// receives `Done{Error}` before the error is returned.
    pub fn serve(&mut self, queue: &SubmitQueue, max_batch: usize) -> Result<()> {
        let metrics = self.metrics.clone();
        let arena = self.arena();
        let cache = self.prefix_cache.clone();
        let policy = ChunkPolicy {
            chunk: self.prefill_chunk,
            budget: self
                .sweep_budget
                .unwrap_or_else(|| max_batch.max(1).saturating_mul(self.prefill_chunk)),
        };
        let res = match &self.kind {
            EngineKind::Native(model) => {
                let mut stepper = NativeStepper { model: model.clone() };
                run_scheduler(
                    &mut stepper,
                    queue,
                    max_batch,
                    policy,
                    metrics.as_ref(),
                    arena.as_deref(),
                    cache.as_deref(),
                )
            }
            EngineKind::Lut(_) => {
                let stepper = self.lut_step.as_mut().context("lut stepper missing")?;
                run_scheduler(
                    stepper,
                    queue,
                    max_batch,
                    policy,
                    metrics.as_ref(),
                    arena.as_deref(),
                    cache.as_deref(),
                )
            }
            EngineKind::Pjrt { model, artifact, cache_len } => {
                let (model, artifact, cache_len) = (model.clone(), artifact.clone(), *cache_len);
                let rt = self.runtime.as_mut().context("pjrt runtime")?;
                let mut stepper = PjrtStepper::new(rt, &model, &artifact, cache_len)?;
                run_scheduler(&mut stepper, queue, max_batch, policy, metrics.as_ref(), None, None)
            }
        };
        if let (Some(m), Some(a)) = (&self.metrics, &arena) {
            m.observe_arena(a.id(), a.stats());
        }
        if let (Some(m), Some(c)) = (&self.metrics, &cache) {
            m.observe_prefix(c.id(), c.stats());
        }
        res
    }

    /// Legacy batch-synchronous API: greedy-decode a fixed batch to
    /// completion. A thin wrapper over the event stream — the same
    /// scheduler runs with `max_batch = reqs.len()` over a pre-filled
    /// queue and each stream is folded into a [`Response`] — kept so
    /// callers (report harness, tests) migrate incrementally.
    pub fn generate_batch(&mut self, reqs: &[Request]) -> Result<Vec<Response>> {
        if reqs.is_empty() {
            return Ok(Vec::new());
        }
        let queue = SubmitQueue::new();
        let rxs: Vec<_> = reqs
            .iter()
            .map(|r| {
                let (tx, rx) = channel();
                queue.push(Pending {
                    request: GenRequest {
                        id: r.id,
                        prompt: r.prompt.clone(),
                        params: SamplingParams { max_new: r.max_new, ..Default::default() },
                        priority: 0,
                    },
                    events: tx,
                    cancel: CancelHandle::new(),
                    enqueued: Instant::now(),
                });
                (r.id, rx)
            })
            .collect();
        queue.close();
        self.serve(&queue, reqs.len())?;
        rxs.iter().map(|(id, rx)| super::collect_events(*id, rx)).collect()
    }
}

struct NativeSession {
    state: DecodeState,
}

impl Session for NativeSession {
    fn pos(&self) -> usize {
        self.state.pos()
    }
    fn capacity(&self) -> usize {
        self.state.capacity()
    }
    fn prefix_match(&mut self, cache: &PrefixCache, prompt: &[u32]) -> usize {
        self.state.prefix_attach(cache, prompt)
    }
    fn prefix_publish(&mut self, cache: &PrefixCache, prompt: &[u32]) {
        if self.state.pos() >= prompt.len() {
            self.state.prefix_publish(cache, prompt);
        }
    }
}

/// Independent per-session stepping — the pre-refactor decode path,
/// bypassing the fused sweep entirely (dense matvecs have no cross-
/// session work to share).
struct NativeStepper {
    model: Arc<Model>,
}

impl Stepper for NativeStepper {
    type Sess = NativeSession;

    fn make(&self) -> NativeSession {
        NativeSession { state: self.model.decode_state() }
    }

    fn step_batch(
        &mut self,
        sessions: &mut [&mut NativeSession],
        tokens: &[u32],
    ) -> Result<Vec<Vec<f32>>> {
        Ok(sessions.iter_mut().zip(tokens).map(|(s, &t)| s.state.step(&self.model, t)).collect())
    }

    fn step_prefill_chunk(&mut self, sess: &mut NativeSession, tokens: &[u32]) -> Result<Vec<f32>> {
        Ok(sess.state.prefill_chunk(&self.model, tokens))
    }
}

/// LUT decode session state: an arena slot handle plus position. The
/// per-step work buffers live in [`BatchedLutStep`], shared across the
/// batch; the KV itself lives in the model's pooled [`KvArena`] (same
/// arena as [`DecodeState`] — identical capacity, identical slot bytes,
/// so the LUT and native engines truncate identically).
struct LutSession {
    arena: Arc<KvArena>,
    /// `Some` for the whole life of the session; taken only in `drop`.
    handle: Option<KvHandle>,
    pos: usize,
    cap: usize,
}

impl Drop for LutSession {
    fn drop(&mut self) {
        if let Some(h) = self.handle.take() {
            self.arena.release(h);
        }
    }
}

impl Session for LutSession {
    fn pos(&self) -> usize {
        self.pos
    }
    fn capacity(&self) -> usize {
        self.cap
    }
    fn prefix_match(&mut self, cache: &PrefixCache, prompt: &[u32]) -> usize {
        let h = self.handle.as_mut().expect("live session");
        let matched = cache.match_and_borrow(prompt, h);
        self.pos = matched;
        matched
    }
    fn prefix_publish(&mut self, cache: &PrefixCache, prompt: &[u32]) {
        // Guard: publication is only sound once every prompt position is
        // stored (the scheduler calls this at prefill completion, so the
        // check is belt-and-braces against future call sites).
        if self.pos >= prompt.len() {
            cache.insert(prompt, self.handle.as_mut().expect("live session"));
        }
    }
}

/// Batched LUT stepper: all active sessions advance together through one
/// fused pass per sweep — shared multi-LUT build, per-layer batched
/// linears ([`lut_gemm`]), and a score/softmax/AV phase that runs as one
/// multi-session pass per (layer, kv-head) over arena-adjacent KV
/// strips. Work buffers are flat `nb × width` slabs reused across
/// sweeps, so the warm decode loop makes no per-session allocations
/// (save for the per-phase slice-of-refs assembly).
struct BatchedLutStep {
    lm: LutModel,
    rope: Arc<Rope>,
    arena: Arc<KvArena>,
    cap: usize,
    scratch: LutScratch,
    // flat per-sweep buffers, b-major (`buf[b*width..(b+1)*width]`)
    h: Vec<f32>,
    normed: Vec<f32>,
    q: Vec<f32>,
    kx: Vec<f32>,
    vx: Vec<f32>,
    attn: Vec<f32>,
    proj: Vec<f32>,
    up: Vec<f32>,
    gate: Vec<f32>,
    mid: Vec<f32>,
    down: Vec<f32>,
    // group-batched score buffer, `group_len × (t+1)`, lane-major
    scores: Vec<f32>,
    // per-page-run staging slice, `group_len × plen`, lane-major — the
    // strip kernels see one page run at a time, scores are scattered
    // into / gathered out of `scores` around each kernel call
    pscores: Vec<f32>,
    // per-call SIMD table scratch for the packed-KV attention kernels
    simd: crate::tensor::SimdScratch,
}

impl BatchedLutStep {
    fn new(lm: LutModel) -> Self {
        let cap = lm.base.decode_capacity();
        // One rope table and one KV arena per model, shared with every
        // DecodeState of the same model.
        let rope = lm.base.rope();
        let arena = lm.base.kv_arena();
        Self {
            lm,
            rope,
            arena,
            cap,
            scratch: LutScratch::default(),
            h: Vec::new(),
            normed: Vec::new(),
            q: Vec::new(),
            kx: Vec::new(),
            vx: Vec::new(),
            attn: Vec::new(),
            proj: Vec::new(),
            up: Vec::new(),
            gate: Vec::new(),
            mid: Vec::new(),
            down: Vec::new(),
            scores: Vec::new(),
            pscores: Vec::new(),
            simd: crate::tensor::SimdScratch::default(),
        }
    }
}

/// One batched linear over flat b-major buffers:
/// `ys[b*d_out..] = packed("l{l}.{name}") · xs[b*d_in..]` for every
/// lane (`xs.len() / d_in` of them — the flat buffers are sized to
/// exactly the sweep batch), through the fused [`lut_gemm`] kernel
/// (which fully overwrites every output row).
fn lin_batch(
    lm: &LutModel,
    l: usize,
    name: &str,
    xs: &[f32],
    d_in: usize,
    ys: &mut Vec<f32>,
    scratch: &mut LutScratch,
) {
    let rec = &lm.packed[&format!("l{l}.{name}")];
    debug_assert_eq!(rec.d_in, d_in);
    debug_assert_eq!(xs.len() % d_in, 0);
    let nb = xs.len() / d_in;
    ys.resize(nb * rec.d_out, 0.0);
    let xrefs: Vec<&[f32]> = xs.chunks_exact(d_in).collect();
    let mut yrefs: Vec<&mut [f32]> = ys.chunks_exact_mut(rec.d_out).collect();
    lut_gemm(rec, &xrefs, &mut yrefs, scratch);
}

/// Reusable slice-collection scratch for [`fused_attention`]: the
/// q-row / K-page / V-page ref vectors the strip kernels consume,
/// refilled per (position group, kv-head, page run) with `clear()` +
/// `extend()`.
/// The non-hot caller constructs it (one allocation site, outside the
/// marked phase); inside the phase the vectors only grow to the group
/// width once and are reused after that. Which side is populated — f32
/// refs or packed strips — follows the arena's [`KvFormat`]; the group
/// loop itself is shared, so the two formats can never diverge in
/// control flow (only the kernel invocations dispatch).
#[derive(Default)]
struct StripRefs<'v> {
    qs: Vec<&'v [f32]>,
    ks: Vec<&'v [f32]>,
    vs: Vec<&'v [f32]>,
    ksp: Vec<PackedStrip<'v>>,
    vsp: Vec<PackedStrip<'v>>,
}

/// Carve disjoint `&mut buf[b*row_len + o0 ..][..sub_len]` sub-slices
/// out of a flat b-major buffer for an **ascending** list of lane
/// indices — the safe-split plumbing that lets the batched AV kernel
/// write every session in a position group in one pass.
fn disjoint_rows_mut<'a>(
    buf: &'a mut [f32],
    row_len: usize,
    lanes: &[usize],
    o0: usize,
    sub_len: usize,
) -> Vec<&'a mut [f32]> {
    let mut rows = buf.chunks_exact_mut(row_len);
    let mut out = Vec::with_capacity(lanes.len());
    let mut next = 0usize;
    for &b in lanes {
        debug_assert!(b >= next, "lanes must be ascending");
        let row = rows.nth(b - next).expect("lane within buffer");
        out.push(&mut row[o0..o0 + sub_len]);
        next = b + 1;
    }
    out
}

/// One layer's batched score/softmax/AV phase: a single multi-session
/// pass per (position group, kv-head), iterated **page run by page
/// run** over the paged arena. All sessions in a group share the score
/// length and the head geometry; for each run `[p0, p0+plen)` every
/// lane contributes *its own* page `pg` (a private page of its slot, or
/// a page shared through the prefix cache — the reader does not care),
/// and the strip kernels walk the whole group together position-major
/// within the run. Per-run scores land lane-major in `pscores`
/// (`gl × plen`) and are scattered into `scores_buf` (`gl × (t+1)`);
/// after the per-lane softmax the AV walk re-gathers each run's weights
/// and accumulates page by page in ascending position order — exactly
/// the accumulation order of a monolithic strip walk, so paging (and
/// page sharing) never changes tokens. The pass dispatches on the
/// arena's format: f32 pages go through [`strip_dots`] /
/// [`strip_axpys`]; packed bit-plane pages through the fused-dequant
/// twins [`strip_dots_packed`] / [`strip_axpys_packed`], which consume
/// the plane words the session step stored — quantization happened
/// once, at store time, never here.
///
/// Hot contract (`bpdq lint` L2–L4): the caller resolves every handle
/// (`views`) and owns the [`StripRefs`] scratch, so this phase itself
/// performs no allocation, panic-path call, or locking in steady state
/// (the ref vectors and staging buffers reach their high-water length
/// on the first sweep and are reused after that).
// lint: hot
#[allow(clippy::too_many_arguments)]
fn fused_attention<'v>(
    format: KvFormat,
    groups: &[(usize, Vec<usize>)],
    views: &'v [KvView<'v>],
    l: usize,
    nkv: usize,
    group: usize,
    hd: usize,
    d: usize,
    scale: f32,
    pp: usize,
    q: &'v [f32],
    attn: &mut [f32],
    scores_buf: &mut Vec<f32>,
    pscores: &mut Vec<f32>,
    refs: &mut StripRefs<'v>,
    simd: &mut crate::tensor::SimdScratch,
) {
    for (t, lanes) in groups {
        let (t, gl) = (*t, lanes.len());
        let len = t + 1;
        scores_buf.resize(gl * len, 0.0);
        for kvh in 0..nkv {
            for g in 0..group {
                let o0 = (kvh * group + g) * hd;
                refs.qs.clear();
                refs.qs.extend(lanes.iter().map(|&b| &q[b * d + o0..b * d + o0 + hd]));
                // scores, one page run at a time
                let (mut p0, mut pg) = (0usize, 0usize);
                while p0 < len {
                    let plen = (len - p0).min(pp);
                    pscores.resize(gl * plen, 0.0);
                    match format {
                        KvFormat::F32 => {
                            refs.ks.clear();
                            refs.ks.extend(
                                lanes.iter().map(|&b| &views[b].k_page(l, kvh, pg)[..plen * hd]),
                            );
                            strip_dots(&refs.qs, &refs.ks, hd, scale, pscores);
                        }
                        KvFormat::BitPlane { .. } => {
                            refs.ksp.clear();
                            refs.ksp
                                .extend(lanes.iter().map(|&b| views[b].k_page_packed(l, kvh, pg)));
                            strip_dots_packed(&refs.qs, &refs.ksp, plen, scale, pscores, simd);
                        }
                    }
                    for (lane, run) in pscores.chunks_exact(plen).enumerate() {
                        scores_buf[lane * len + p0..lane * len + p0 + plen].copy_from_slice(run);
                    }
                    p0 += plen;
                    pg += 1;
                }
                for lane_scores in scores_buf[..gl * len].chunks_exact_mut(len) {
                    softmax(lane_scores);
                }
                // AV, accumulated across page runs in position order
                let mut outs = disjoint_rows_mut(attn, d, lanes, o0, hd);
                let (mut p0, mut pg) = (0usize, 0usize);
                while p0 < len {
                    let plen = (len - p0).min(pp);
                    pscores.resize(gl * plen, 0.0);
                    for (lane, run) in pscores.chunks_exact_mut(plen).enumerate() {
                        run.copy_from_slice(&scores_buf[lane * len + p0..lane * len + p0 + plen]);
                    }
                    match format {
                        KvFormat::F32 => {
                            refs.vs.clear();
                            refs.vs.extend(
                                lanes.iter().map(|&b| &views[b].v_page(l, kvh, pg)[..plen * hd]),
                            );
                            strip_axpys(pscores, &refs.vs, hd, &mut outs);
                        }
                        KvFormat::BitPlane { .. } => {
                            refs.vsp.clear();
                            refs.vsp
                                .extend(lanes.iter().map(|&b| views[b].v_page_packed(l, kvh, pg)));
                            strip_axpys_packed(pscores, &refs.vsp, plen, &mut outs);
                        }
                    }
                    p0 += plen;
                    pg += 1;
                }
            }
        }
    }
}

impl Stepper for BatchedLutStep {
    type Sess = LutSession;

    fn make(&self) -> LutSession {
        LutSession {
            arena: self.arena.clone(),
            handle: Some(self.arena.acquire().expect("KV arena exhausted")),
            pos: 0,
            cap: self.cap,
        }
    }

    fn step_batch(
        &mut self,
        sessions: &mut [&mut LutSession],
        tokens: &[u32],
    ) -> Result<Vec<Vec<f32>>> {
        let nb = sessions.len();
        debug_assert_eq!(tokens.len(), nb);
        if nb == 0 {
            return Ok(Vec::new());
        }
        // Arc clone so `model` does not borrow `self` (the flat buffers
        // below need disjoint &mut borrows of self's fields).
        let model = self.lm.base.clone();
        let cfg = &model.cfg;
        let (d, nh, nkv, hd) = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim());
        let kvd = cfg.kv_dim();
        let dff = cfg.d_ff;
        let group = cfg.kv_group();
        let scale = 1.0 / (hd as f32).sqrt();

        self.h.clear();
        for (&tok, sess) in tokens.iter().zip(sessions.iter()) {
            assert!(sess.pos < sess.cap, "KV cache exhausted");
            let id = (tok as usize).min(cfg.vocab_size - 1);
            self.h.extend_from_slice(model.embed.row(id));
        }
        self.normed.resize(nb * d, 0.0);

        // Group sweep lanes by decode position (stable within the sweep:
        // positions advance only at the end). Lanes at equal positions
        // share the score length, so each (layer, kv-head) below is one
        // uniform batched pass per group — ascending lane order inside a
        // group both keeps the output deterministic and lets the AV
        // writer carve disjoint sub-slices front to back.
        let mut order: Vec<usize> = (0..nb).collect();
        order.sort_unstable_by_key(|&b| sessions[b].pos);
        let mut groups: Vec<(usize, Vec<usize>)> = Vec::new();
        for &b in &order {
            let t = sessions[b].pos;
            match groups.last_mut() {
                Some((gt, lanes)) if *gt == t => lanes.push(b),
                _ => groups.push((t, vec![b])),
            }
        }
        for (_, lanes) in &mut groups {
            lanes.sort_unstable();
        }

        for l in 0..cfg.n_layers {
            let lw = &model.layers[l];

            // ---- attention (GQA: `group` q heads per kv head) ----
            for b in 0..nb {
                let (h0, h1) = (b * d, (b + 1) * d);
                rmsnorm(&self.h[h0..h1], &lw.norm1, &mut self.normed[h0..h1]);
            }
            lin_batch(&self.lm, l, "wq", &self.normed, d, &mut self.q, &mut self.scratch);
            lin_batch(&self.lm, l, "wk", &self.normed, d, &mut self.kx, &mut self.scratch);
            lin_batch(&self.lm, l, "wv", &self.normed, d, &mut self.vx, &mut self.scratch);

            for (b, sess) in sessions.iter_mut().enumerate() {
                let t = sess.pos;
                let qb = &mut self.q[b * d..(b + 1) * d];
                for hh in 0..nh {
                    self.rope.apply(&mut qb[hh * hd..(hh + 1) * hd], t);
                }
                let kxb = &mut self.kx[b * kvd..(b + 1) * kvd];
                for hh in 0..nkv {
                    self.rope.apply(&mut kxb[hh * hd..(hh + 1) * hd], t);
                }
                let mut kv = self.arena.view_mut(sess.handle.as_mut().expect("live session"));
                kv.store_k(l, t, &self.kx[b * kvd..(b + 1) * kvd]);
                kv.store_v(l, t, &self.vx[b * kvd..(b + 1) * kvd]);
            }
            self.attn.clear();
            self.attn.resize(nb * d, 0.0);

            // Batched score/softmax/AV — see [`fused_attention`]. The
            // handle resolution (fallible `expect`) and the scratch
            // construction happen here, outside the hot-marked phase.
            let format = self.arena.geom().format;
            let pp = self.arena.geom().page_positions;
            let arena = &self.arena;
            let views: Vec<KvView> = sessions
                .iter()
                .map(|s| arena.view(s.handle.as_ref().expect("live session")))
                .collect();
            let mut strip_refs = StripRefs::default();
            fused_attention(
                format,
                &groups,
                &views,
                l,
                nkv,
                group,
                hd,
                d,
                scale,
                pp,
                &self.q,
                &mut self.attn[..nb * d],
                &mut self.scores,
                &mut self.pscores,
                &mut strip_refs,
                &mut self.simd,
            );
            drop(strip_refs);
            drop(views);

            lin_batch(&self.lm, l, "wo", &self.attn, d, &mut self.proj, &mut self.scratch);
            for (hi, p) in self.h[..nb * d].iter_mut().zip(self.proj[..nb * d].iter()) {
                *hi += p;
            }

            // ---- MLP (SwiGLU) ----
            for b in 0..nb {
                let (h0, h1) = (b * d, (b + 1) * d);
                rmsnorm(&self.h[h0..h1], &lw.norm2, &mut self.normed[h0..h1]);
            }
            lin_batch(&self.lm, l, "w1", &self.normed, d, &mut self.up, &mut self.scratch);
            lin_batch(&self.lm, l, "w3", &self.normed, d, &mut self.gate, &mut self.scratch);
            self.mid.resize(nb * dff, 0.0);
            for ((m, &u), &gt) in self.mid[..nb * dff]
                .iter_mut()
                .zip(self.up[..nb * dff].iter())
                .zip(self.gate[..nb * dff].iter())
            {
                *m = u * silu(gt);
            }
            lin_batch(&self.lm, l, "w2", &self.mid, dff, &mut self.down, &mut self.scratch);
            for (hi, dn) in self.h[..nb * d].iter_mut().zip(self.down[..nb * d].iter()) {
                *hi += dn;
            }
        }

        let mut out = Vec::with_capacity(nb);
        for (b, sess) in sessions.iter_mut().enumerate() {
            sess.pos += 1;
            let normb = &mut self.normed[b * d..(b + 1) * d];
            rmsnorm(&self.h[b * d..(b + 1) * d], &model.norm_f, normb);
            out.push(matvec(&model.lm_head, normb));
        }
        Ok(out)
    }

    /// Fused chunked prefill: the chunk's positions become the sweep
    /// lanes of ONE session. Each layer runs the same batched linears
    /// as [`BatchedLutStep::step_batch`] (`n` lanes of one multi-LUT
    /// build), then stores the whole chunk's K/V as one bulk run per
    /// strip (one ownership/packed-view resolution per touched page —
    /// byte-identical to per-token stores), then reuses
    /// [`fused_attention`] with **singleton position groups**
    /// `[(t0,[0]), (t0+1,[1]), …]`, every lane viewing the same
    /// handle: lane `j`'s score length `t0+j+1` caps its page-run
    /// walk, so the in-chunk causal block falls out of store-first
    /// ordering with no masking. Per-lane kernels and accumulation
    /// order are exactly the single-token path's, so the chunk is
    /// token-identical to feeding it one sweep at a time. Only the
    /// final position's logits are computed (earlier positions predict
    /// known prompt tokens).
    fn step_prefill_chunk(&mut self, sess: &mut LutSession, tokens: &[u32]) -> Result<Vec<f32>> {
        let n = tokens.len();
        if n == 0 {
            return Ok(Vec::new());
        }
        if n == 1 {
            let mut lane = [&mut *sess];
            let mut out = self.step_batch(&mut lane, tokens)?;
            return Ok(out.pop().unwrap_or_default());
        }
        let model = self.lm.base.clone();
        let cfg = &model.cfg;
        let (d, nh, nkv, hd) = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim());
        let kvd = cfg.kv_dim();
        let dff = cfg.d_ff;
        let group = cfg.kv_group();
        let scale = 1.0 / (hd as f32).sqrt();
        let t0 = sess.pos;
        assert!(t0 + n <= sess.cap, "KV cache exhausted");

        self.h.clear();
        for &tok in tokens {
            let id = (tok as usize).min(cfg.vocab_size - 1);
            self.h.extend_from_slice(model.embed.row(id));
        }
        self.normed.resize(n * d, 0.0);

        // Consecutive positions of one session: singleton groups in
        // ascending position order (lane j at t0 + j).
        let groups: Vec<(usize, Vec<usize>)> = (0..n).map(|j| (t0 + j, vec![j])).collect();

        for l in 0..cfg.n_layers {
            let lw = &model.layers[l];

            for b in 0..n {
                let (h0, h1) = (b * d, (b + 1) * d);
                rmsnorm(&self.h[h0..h1], &lw.norm1, &mut self.normed[h0..h1]);
            }
            lin_batch(&self.lm, l, "wq", &self.normed, d, &mut self.q, &mut self.scratch);
            lin_batch(&self.lm, l, "wk", &self.normed, d, &mut self.kx, &mut self.scratch);
            lin_batch(&self.lm, l, "wv", &self.normed, d, &mut self.vx, &mut self.scratch);

            for j in 0..n {
                let t = t0 + j;
                let qb = &mut self.q[j * d..(j + 1) * d];
                for hh in 0..nh {
                    self.rope.apply(&mut qb[hh * hd..(hh + 1) * hd], t);
                }
                let kxb = &mut self.kx[j * kvd..(j + 1) * kvd];
                for hh in 0..nkv {
                    self.rope.apply(&mut kxb[hh * hd..(hh + 1) * hd], t);
                }
            }
            // Whole-chunk store first, then attend: later in-chunk rows
            // exist but are never read past each lane's score length.
            {
                let mut kv = self.arena.view_mut(sess.handle.as_mut().expect("live session"));
                kv.store_k_run(l, t0, &self.kx[..n * kvd]);
                kv.store_v_run(l, t0, &self.vx[..n * kvd]);
            }
            self.attn.clear();
            self.attn.resize(n * d, 0.0);

            let format = self.arena.geom().format;
            let pp = self.arena.geom().page_positions;
            let arena = &self.arena;
            let handle = sess.handle.as_ref().expect("live session");
            let views: Vec<KvView> = (0..n).map(|_| arena.view(handle)).collect();
            let mut strip_refs = StripRefs::default();
            fused_attention(
                format,
                &groups,
                &views,
                l,
                nkv,
                group,
                hd,
                d,
                scale,
                pp,
                &self.q,
                &mut self.attn[..n * d],
                &mut self.scores,
                &mut self.pscores,
                &mut strip_refs,
                &mut self.simd,
            );
            drop(strip_refs);
            drop(views);

            lin_batch(&self.lm, l, "wo", &self.attn, d, &mut self.proj, &mut self.scratch);
            for (hi, p) in self.h[..n * d].iter_mut().zip(self.proj[..n * d].iter()) {
                *hi += p;
            }

            for b in 0..n {
                let (h0, h1) = (b * d, (b + 1) * d);
                rmsnorm(&self.h[h0..h1], &lw.norm2, &mut self.normed[h0..h1]);
            }
            lin_batch(&self.lm, l, "w1", &self.normed, d, &mut self.up, &mut self.scratch);
            lin_batch(&self.lm, l, "w3", &self.normed, d, &mut self.gate, &mut self.scratch);
            self.mid.resize(n * dff, 0.0);
            for ((m, &u), &gt) in self.mid[..n * dff]
                .iter_mut()
                .zip(self.up[..n * dff].iter())
                .zip(self.gate[..n * dff].iter())
            {
                *m = u * silu(gt);
            }
            lin_batch(&self.lm, l, "w2", &self.mid, dff, &mut self.down, &mut self.scratch);
            for (hi, dn) in self.h[..n * d].iter_mut().zip(self.down[..n * d].iter()) {
                *hi += dn;
            }
        }

        sess.pos += n;
        let b = n - 1;
        let normb = &mut self.normed[b * d..(b + 1) * d];
        rmsnorm(&self.h[b * d..(b + 1) * d], &model.norm_f, normb);
        Ok(matvec(&model.lm_head, normb))
    }
}

/// KV-cache width the AOT decode artifact was lowered with, from the
/// `kv_dim` line of its sibling `.meta` file (written by
/// `python/compile/aot.py` since the GQA-aware lowering). `None` marks a
/// stale TLM1-era artifact that threads `d_model`-wide caches.
fn artifact_kv_dim(artifact: &std::path::Path) -> Option<usize> {
    let name = artifact.file_name()?.to_str()?;
    let base = name.strip_suffix(".hlo.txt").unwrap_or(name);
    let meta = artifact.with_file_name(format!("{base}.meta"));
    let text = std::fs::read_to_string(meta).ok()?;
    text.lines().find_map(|line| line.strip_prefix("kv_dim ")?.trim().parse().ok())
}

/// A PJRT decode session: the KV cache travels as a pair of literals
/// threaded through the AOT executable, one `run` per step.
struct PjrtSession {
    klit: xla::Literal,
    vlit: xla::Literal,
    pos: usize,
    cap: usize,
}

impl Session for PjrtSession {
    fn pos(&self) -> usize {
        self.pos
    }
    fn capacity(&self) -> usize {
        self.cap
    }
}

/// PJRT stepper: sequential AOT-executable calls per session (the
/// artifact is single-token). The executable is loaded (and compiled,
/// on a cache miss) **once per serve loop**, not per request —
/// reloading inside the request loop made every request pay the
/// artifact parse/compile round-trip.
struct PjrtStepper<'rt> {
    exe: &'rt LoadedExecutable,
    nl: usize,
    cache_len: usize,
    kv_dim: usize,
}

impl<'rt> PjrtStepper<'rt> {
    fn new(
        rt: &'rt mut Runtime,
        model: &Model,
        artifact: &std::path::Path,
        cache_len: usize,
    ) -> Result<Self> {
        // GQA-aware artifacts declare their cache width (`kv_dim`) in the
        // sibling meta file and must match the checkpoint exactly. Stale
        // TLM1-era artifacts (no kv_dim line) thread a full d_model-wide
        // cache, so only MHA checkpoints may use them — refuse rather than
        // silently mis-shape the cache literals.
        let kv_dim = match artifact_kv_dim(artifact) {
            Some(kd) => {
                anyhow::ensure!(
                    kd == model.cfg.kv_dim(),
                    "decode artifact kv_dim {kd} != checkpoint kv_dim {} — regenerate with \
                     python -m compile.aot",
                    model.cfg.kv_dim()
                );
                kd
            }
            None => {
                anyhow::ensure!(
                    model.cfg.n_kv_heads == model.cfg.n_heads,
                    "stale decode artifact (no kv_dim in meta) supports MHA only — regenerate \
                     with python -m compile.aot for GQA checkpoints"
                );
                model.cfg.d_model
            }
        };
        let exe = rt.load(artifact)?;
        Ok(Self { exe, nl: model.cfg.n_layers, cache_len, kv_dim })
    }
}

impl Stepper for PjrtStepper<'_> {
    type Sess = PjrtSession;

    fn make(&self) -> PjrtSession {
        let zeros = vec![0.0f32; self.nl * self.cache_len * self.kv_dim];
        let shape = [self.nl as i64, self.cache_len as i64, self.kv_dim as i64];
        PjrtSession {
            klit: runtime::literal_f32(&zeros, &shape).expect("PJRT cache literal"),
            vlit: runtime::literal_f32(&zeros, &shape).expect("PJRT cache literal"),
            pos: 0,
            cap: self.cache_len,
        }
    }

    fn step_batch(
        &mut self,
        sessions: &mut [&mut PjrtSession],
        tokens: &[u32],
    ) -> Result<Vec<Vec<f32>>> {
        let mut out = Vec::with_capacity(sessions.len());
        for (s, &t) in sessions.iter_mut().zip(tokens) {
            // Move the cache literals into the call; a cheap scalar
            // placeholder keeps the session valid if `run` fails.
            let klit = std::mem::replace(&mut s.klit, runtime::literal_i32(0));
            let vlit = std::mem::replace(&mut s.vlit, runtime::literal_i32(0));
            let res = self.exe.run(&[
                runtime::literal_i32(t as i32),
                runtime::literal_i32(s.pos as i32),
                klit,
                vlit,
            ])?;
            let mut it = res.into_iter();
            let logits = runtime::to_f32_vec(&it.next().context("logits")?)?;
            s.klit = it.next().context("kcache")?;
            s.vlit = it.next().context("vcache")?;
            s.pos += 1;
            out.push(logits);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::tlm::TlmFile;
    use crate::model::{synthetic_model, ModelConfig};
    use crate::quant::{BpdqConfig, QuantMethod};
    use crate::serving::{FinishReason, GenEvent, Usage};
    use std::path::Path;
    use std::sync::mpsc::Receiver;

    fn tiny() -> Arc<Model> {
        tiny_gqa(4)
    }

    /// 4-head tiny model with `n_kv_heads` kv heads (4 = MHA, 2 = GQA,
    /// 1 = MQA).
    fn tiny_gqa(n_kv_heads: usize) -> Arc<Model> {
        Arc::new(synthetic_model(
            &ModelConfig {
                vocab_size: 20,
                d_model: 32,
                n_layers: 2,
                n_heads: 4,
                n_kv_heads,
                d_ff: 48,
                max_seq: 32,
                kv_format: KvFormat::F32,
            },
            3,
        ))
    }

    fn reqs(n: usize) -> Vec<Request> {
        (0..n)
            .map(|i| Request {
                id: i as u64,
                prompt: (0..5).map(|t| ((t + i) % 20) as u32).collect(),
                max_new: 4,
            })
            .collect()
    }

    /// Quantize `model` with BPDQ and build (native-on-dequant, LUT)
    /// engines over the same weights.
    fn quantized_engine_pair(model: Arc<Model>, group_size: usize) -> (Engine, Engine) {
        let vocab = model.cfg.vocab_size;
        let calib: Vec<Vec<u32>> = (0..4)
            .map(|i| (0..20).map(|t| ((t * 3 + i) % vocab) as u32).collect())
            .collect();
        let method = QuantMethod::Bpdq(BpdqConfig {
            k: 2,
            group_size,
            iters: 2,
            gar: false,
            ..Default::default()
        });
        let qm = crate::model::pipeline::quantize_model(&model, &calib, &method).unwrap();
        let packed: HashMap<String, BitPlanePacked> = qm
            .packed
            .iter()
            .map(|(k, v)| (k.clone(), v.as_bit_planes().unwrap().clone()))
            .collect();
        let qmodel = Arc::new(qm.model.clone());
        let native = Engine::new(EngineKind::Native(qmodel.clone())).unwrap();
        let lut = Engine::new(EngineKind::Lut(LutModel::new(qmodel, packed).unwrap())).unwrap();
        (native, lut)
    }

    /// Push `gen_reqs` onto a fresh queue, serve it to completion with
    /// `max_batch`, and drain each stream.
    fn serve_streams(
        engine: &mut Engine,
        gen_reqs: Vec<GenRequest>,
        max_batch: usize,
    ) -> Vec<(Vec<u32>, FinishReason, Usage)> {
        let queue = SubmitQueue::new();
        let rxs: Vec<Receiver<GenEvent>> = gen_reqs
            .into_iter()
            .map(|request| {
                let (tx, rx) = channel();
                queue.push(Pending {
                    request,
                    events: tx,
                    cancel: CancelHandle::new(),
                    enqueued: Instant::now(),
                });
                rx
            })
            .collect();
        queue.close();
        engine.serve(&queue, max_batch).unwrap();
        rxs.iter()
            .map(|rx| {
                let mut tokens = Vec::new();
                loop {
                    match rx.recv().expect("stream ends with Done") {
                        GenEvent::Token { id, .. } => tokens.push(id),
                        GenEvent::Done { finish_reason, usage, .. } => {
                            return (tokens, finish_reason, usage)
                        }
                    }
                }
            })
            .collect()
    }

    #[test]
    fn native_engine_batch() {
        let mut e = Engine::new(EngineKind::Native(tiny())).unwrap();
        let rs = e.generate_batch(&reqs(3)).unwrap();
        assert_eq!(rs.len(), 3);
        for (i, r) in rs.iter().enumerate() {
            assert_eq!(r.id, i as u64);
            assert_eq!(r.tokens.len(), 4);
            assert!(r.first_token_us <= r.total_us);
        }
    }

    #[test]
    fn batch_matches_sequential() {
        // Continuous batching must not change results.
        let model = tiny();
        let mut e = Engine::new(EngineKind::Native(model.clone())).unwrap();
        let batch = e.generate_batch(&reqs(3)).unwrap();
        for (i, r) in reqs(3).iter().enumerate() {
            let single = e.generate_batch(std::slice::from_ref(r)).unwrap();
            assert_eq!(single[0].tokens, batch[i].tokens, "request {i}");
        }
    }

    #[test]
    fn event_stream_matches_generate_batch() {
        // Acceptance: temp=0 event-stream output is token-identical to
        // the legacy batch wrapper for the same prompts — Native and LUT.
        for (mut engine, label) in {
            let (native, lut) = quantized_engine_pair(tiny(), 16);
            [(native, "native"), (lut, "lut")]
        } {
            let legacy = engine.generate_batch(&reqs(3)).unwrap();
            let gen_reqs: Vec<GenRequest> = reqs(3)
                .iter()
                .map(|r| GenRequest {
                    id: r.id,
                    prompt: r.prompt.clone(),
                    params: SamplingParams { max_new: r.max_new, ..Default::default() },
                    priority: 0,
                })
                .collect();
            let streamed = serve_streams(&mut engine, gen_reqs, 3);
            for (i, ((tokens, fin, usage), legacy_r)) in
                streamed.iter().zip(&legacy).enumerate()
            {
                assert_eq!(tokens, &legacy_r.tokens, "{label} request {i}");
                assert_eq!(*fin, FinishReason::Length, "{label} request {i}");
                assert_eq!(usage.completion_tokens, tokens.len());
                assert_eq!(usage.prompt_tokens, 5);
            }
        }
    }

    #[test]
    fn mid_sweep_admission_parity_lut() {
        // Satellite: a request admitted into a busy sweep at temp=0 must
        // produce tokens identical to running it solo. max_batch 2 makes
        // the join deterministic: the third request is admitted only when
        // the second retires, while the long first is still decoding.
        let (_, mut lut) = quantized_engine_pair(tiny(), 16);
        let joiner_prompt: Vec<u32> = vec![2, 9, 14];
        let solo = lut
            .generate_batch(&[Request { id: 9, prompt: joiner_prompt.clone(), max_new: 6 }])
            .unwrap();
        let gen_reqs = vec![
            GenRequest {
                id: 0,
                prompt: vec![1, 4],
                params: SamplingParams { max_new: 40, ..Default::default() },
                priority: 0,
            },
            GenRequest {
                id: 1,
                prompt: vec![7],
                params: SamplingParams { max_new: 2, ..Default::default() },
                priority: 0,
            },
            GenRequest {
                id: 2,
                prompt: joiner_prompt,
                params: SamplingParams { max_new: 6, ..Default::default() },
                priority: 0,
            },
        ];
        let out = serve_streams(&mut lut, gen_reqs, 2);
        assert_eq!(out[2].0, solo[0].tokens, "mid-sweep admission changed tokens");
        assert!(
            out[2].2.finished_sweep > out[1].2.finished_sweep,
            "joiner admitted after the early request retired"
        );
        assert!(
            out[2].2.finished_sweep < out[0].2.finished_sweep,
            "joiner must finish inside the long request's decode"
        );
    }

    #[test]
    fn seeded_sampling_is_reproducible() {
        let mut e = Engine::new(EngineKind::Native(tiny())).unwrap();
        let req = |seed: u64| GenRequest {
            id: seed,
            prompt: vec![1, 2, 3],
            params: SamplingParams {
                temperature: 0.9,
                top_k: 8,
                top_p: 0.95,
                seed,
                max_new: 10,
                ..Default::default()
            },
            priority: 0,
        };
        let a = serve_streams(&mut e, vec![req(7)], 1);
        let b = serve_streams(&mut e, vec![req(7)], 1);
        assert_eq!(a[0].0, b[0].0, "same seed ⇒ same stream");
        assert_eq!(a[0].0.len(), 10);
        assert!(a[0].0.iter().all(|&t| (t as usize) < 20), "tokens within vocab");
    }

    #[test]
    fn stop_token_finishes_stream() {
        // Use the first greedy token as the stop token: the stream must
        // end immediately with Stop and emit nothing.
        let mut e = Engine::new(EngineKind::Native(tiny())).unwrap();
        let greedy = e
            .generate_batch(&[Request { id: 0, prompt: vec![1, 2, 3], max_new: 4 }])
            .unwrap();
        let stop = greedy[0].tokens[0];
        let out = serve_streams(
            &mut e,
            vec![GenRequest {
                id: 1,
                prompt: vec![1, 2, 3],
                params: SamplingParams {
                    max_new: 4,
                    stop_tokens: vec![stop],
                    ..Default::default()
                },
                priority: 0,
            }],
            1,
        );
        assert!(out[0].0.is_empty(), "stop token must not be emitted");
        assert_eq!(out[0].1, FinishReason::Stop);
    }

    #[test]
    fn lut_engine_matches_native_on_quantized_model() {
        // Quantize with BPDQ, then: native decode over dequantized weights
        // must equal batched LUT decode over the packed records — at every
        // kv-head count (MQA / GQA / MHA).
        for n_kv in [1usize, 2, 4] {
            let (mut native, mut lut) = quantized_engine_pair(tiny_gqa(n_kv), 16);
            let rs_native = native.generate_batch(&reqs(2)).unwrap();
            let rs_lut = lut.generate_batch(&reqs(2)).unwrap();
            for (a, b) in rs_native.iter().zip(&rs_lut) {
                assert_eq!(a.tokens, b.tokens, "n_kv_heads {n_kv}");
            }
        }
    }

    #[test]
    fn gqa_batched_decode_parity_ragged_prompts() {
        // The grouped-by-position fused attention must be token-identical
        // to the native engine and to B=1 LUT decode under GQA, with
        // ragged prompts (several distinct position groups per sweep).
        for n_kv in [1usize, 2] {
            let (mut native, mut lut) = quantized_engine_pair(tiny_gqa(n_kv), 16);
            let ragged: Vec<Request> = (0..4)
                .map(|i| Request {
                    id: i as u64,
                    prompt: (0..(1 + 2 * i)).map(|t| ((t * 5 + i) % 20) as u32).collect(),
                    max_new: 3 + i,
                })
                .collect();
            let rs_native = native.generate_batch(&ragged).unwrap();
            let rs_batch = lut.generate_batch(&ragged).unwrap();
            for (i, (a, b)) in rs_native.iter().zip(&rs_batch).enumerate() {
                assert_eq!(a.tokens, b.tokens, "n_kv {n_kv} native vs lut, request {i}");
            }
            for (i, r) in ragged.iter().enumerate() {
                let single = lut.generate_batch(std::slice::from_ref(r)).unwrap();
                assert_eq!(
                    single[0].tokens, rs_batch[i].tokens,
                    "n_kv {n_kv} B=1 vs batched, request {i}"
                );
            }
        }
    }

    #[test]
    fn lut_batched_decode_parity_ragged_prompts() {
        // The fused batched sweep must be token-identical to (a) the
        // native engine and (b) the LUT engine run one request at a time,
        // including with ragged prompt lengths and max_new (sessions
        // leave the batch at different sweeps).
        let (mut native, mut lut) = quantized_engine_pair(tiny(), 16);
        let ragged: Vec<Request> = (0..4)
            .map(|i| Request {
                id: i as u64,
                prompt: (0..(1 + 2 * i)).map(|t| ((t * 5 + i) % 20) as u32).collect(),
                max_new: 3 + i,
            })
            .collect();
        let rs_native = native.generate_batch(&ragged).unwrap();
        let rs_batch = lut.generate_batch(&ragged).unwrap();
        for (i, (a, b)) in rs_native.iter().zip(&rs_batch).enumerate() {
            assert_eq!(a.tokens, b.tokens, "native vs lut, request {i}");
            assert_eq!(b.tokens.len(), ragged[i].max_new, "request {i} length");
        }
        for (i, r) in ragged.iter().enumerate() {
            let single = lut.generate_batch(std::slice::from_ref(r)).unwrap();
            assert_eq!(single[0].tokens, rs_batch[i].tokens, "B=1 vs batched, request {i}");
        }
    }

    #[test]
    fn lut_matches_native_with_quantized_kv_within_tolerance() {
        // Satellite: LUT-vs-native decode parity with a quantized KV
        // arena. The f32-KV parity tests stay token-exact; quantized
        // paths are compared at the logits level within tolerance —
        // store-time quantization rounds each engine's (slightly
        // different, kernel-order-dependent) K/V rows onto the grid, so
        // bit-exactness across *different* linear kernels is not a
        // design guarantee the way it is within one engine.
        for bits in [2usize, 4] {
            let base = Arc::new(tiny_gqa(2).with_kv_format(KvFormat::bit_plane(bits)));
            let vocab = base.cfg.vocab_size;
            let calib: Vec<Vec<u32>> = (0..4)
                .map(|i| (0..20).map(|t| ((t * 3 + i) % vocab) as u32).collect())
                .collect();
            let method = QuantMethod::Bpdq(BpdqConfig {
                k: 2,
                group_size: 16,
                iters: 2,
                gar: false,
                ..Default::default()
            });
            let qm = crate::model::pipeline::quantize_model(&base, &calib, &method).unwrap();
            let packed: HashMap<String, BitPlanePacked> = qm
                .packed
                .iter()
                .map(|(k, v)| (k.clone(), v.as_bit_planes().unwrap().clone()))
                .collect();
            let qmodel = Arc::new(qm.model.clone());
            let mut lut_step =
                BatchedLutStep::new(LutModel::new(qmodel.clone(), packed).unwrap());
            let mut lut_sess = lut_step.make();
            let mut native_sess = qmodel.decode_state();
            for &tok in &[3u32, 7, 1, 12, 5, 9] {
                let lut_logits = {
                    let mut refs = [&mut lut_sess];
                    lut_step.step_batch(&mut refs, &[tok]).unwrap().remove(0)
                };
                let native_logits = native_sess.step(&qmodel, tok);
                let dist: f64 = lut_logits
                    .iter()
                    .zip(&native_logits)
                    .map(|(&a, &b)| ((a - b) as f64).powi(2))
                    .sum::<f64>()
                    .sqrt();
                let norm: f64 =
                    native_logits.iter().map(|&b| (b as f64).powi(2)).sum::<f64>().sqrt();
                // Generous bound: identical-by-construction up to grid
                // threshold flips, each worth at most a few percent.
                assert!(
                    dist <= 0.25 * (norm + 1.0),
                    "kv bits {bits}: LUT vs native logits diverged ({dist} vs norm {norm})"
                );
            }
        }
    }

    #[test]
    fn lut_batched_matches_b1_with_quantized_kv() {
        // Within ONE engine the packed path is bit-deterministic:
        // per-lane LUT builds, stores, and the packed strip kernels all
        // accumulate in the same order at any batch size, so batched
        // quantized-KV decode stays token-identical to B=1 — including
        // ragged prompts (several position groups per sweep).
        let base = Arc::new(tiny_gqa(2).with_kv_format(KvFormat::bit_plane(2)));
        let (_, mut lut) = quantized_engine_pair(base, 16);
        let ragged: Vec<Request> = (0..4)
            .map(|i| Request {
                id: i as u64,
                prompt: (0..(1 + 2 * i)).map(|t| ((t * 5 + i) % 20) as u32).collect(),
                max_new: 3 + i,
            })
            .collect();
        let rs_batch = lut.generate_batch(&ragged).unwrap();
        for (i, r) in ragged.iter().enumerate() {
            assert_eq!(rs_batch[i].tokens.len(), r.max_new, "request {i} length");
            let single = lut.generate_batch(std::slice::from_ref(r)).unwrap();
            assert_eq!(
                single[0].tokens, rs_batch[i].tokens,
                "quantized-KV B=1 vs batched, request {i}"
            );
        }
    }

    #[test]
    fn prefix_cache_hit_is_token_identical_all_kv_bits() {
        // Tentpole parity bar: a cache-hit session (prompt prefix
        // borrowed from the radix cache, only the suffix prefilled) must
        // decode token-identically to a cold session — at f32 KV and at
        // every packed kv_bits. kv_page 2 forces the borrowed prefix to
        // span multiple pages, and the extended prompt exercises borrow
        // + first-divergent-store COW end to end.
        for bits in [0usize, 2, 3, 4] {
            let base = if bits == 0 {
                Arc::new(tiny_gqa(2).with_kv_page(2))
            } else {
                Arc::new(tiny_gqa(2).with_kv_format(KvFormat::bit_plane(bits)).with_kv_page(2))
            };
            let (_, mut lut) = quantized_engine_pair(base, 16);
            let req = Request { id: 0, prompt: vec![3, 7, 1, 12, 5], max_new: 6 };
            let ext = Request { id: 1, prompt: vec![3, 7, 1, 12, 5, 9, 2], max_new: 4 };
            let cold = lut.generate_batch(std::slice::from_ref(&req)).unwrap();
            let cold_ext = lut.generate_batch(std::slice::from_ref(&ext)).unwrap();
            lut.enable_prefix_cache();
            let warm1 = lut.generate_batch(std::slice::from_ref(&req)).unwrap();
            let warm2 = lut.generate_batch(std::slice::from_ref(&req)).unwrap();
            let warm_ext = lut.generate_batch(std::slice::from_ref(&ext)).unwrap();
            assert_eq!(warm1[0].tokens, cold[0].tokens, "bits {bits}: publishing run diverged");
            assert_eq!(warm2[0].tokens, cold[0].tokens, "bits {bits}: cache-hit run diverged");
            assert_eq!(
                warm_ext[0].tokens, cold_ext[0].tokens,
                "bits {bits}: extended-prompt hit diverged"
            );
            let st = lut.prefix_cache().unwrap().stats();
            assert!(st.hits >= 2, "bits {bits}: expected cache hits, got {st:?}");
            assert!(st.hit_tokens >= 9, "bits {bits}: {st:?}");
            let arena = lut.arena().unwrap().stats();
            assert_eq!(arena.slots_in_use, 0, "bits {bits}: sessions must drain");
            assert!(arena.pages_in_use > 0, "bits {bits}: cache retains prefix pages");
            assert!(
                arena.cow_copies >= 1,
                "bits {bits}: extended prompt must COW its first divergent page"
            );
        }
    }

    #[test]
    fn chunked_prefill_token_identical_all_kv_bits() {
        // Tentpole parity bar: chunked prefill (every chunk size —
        // ragged splits and one covering the whole prompt) must be
        // token-identical to one-token-per-sweep prefill, native and
        // LUT, at f32 KV and every packed kv_bits, across small pages.
        for bits in [0usize, 2, 3, 4] {
            let base = if bits == 0 {
                Arc::new(tiny_gqa(2).with_kv_page(2))
            } else {
                Arc::new(tiny_gqa(2).with_kv_format(KvFormat::bit_plane(bits)).with_kv_page(2))
            };
            let (mut native, mut lut) = quantized_engine_pair(base, 16);
            let reqs_v = vec![
                Request {
                    id: 0,
                    prompt: (0..13).map(|t| ((t * 5 + 3) % 20) as u32).collect(),
                    max_new: 4,
                },
                Request { id: 1, prompt: vec![2, 9, 14], max_new: 4 },
            ];
            for engine in [&mut native, &mut lut] {
                engine.configure_prefill(1, None);
                let baseline = engine.generate_batch(&reqs_v).unwrap();
                for chunk in [2usize, 3, 5, 16] {
                    engine.configure_prefill(chunk, None);
                    let chunked = engine.generate_batch(&reqs_v).unwrap();
                    for (i, (a, b)) in baseline.iter().zip(&chunked).enumerate() {
                        assert_eq!(
                            a.tokens,
                            b.tokens,
                            "bits {bits} chunk {chunk} {} request {i}",
                            engine.kind_name()
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn chunked_prefill_with_prefix_cache_parity() {
        // Chunking composes with the radix cache: the cache-miss suffix
        // is what gets chunked, publication still happens once at
        // suffix completion, and both the publishing (cold-miss) run
        // and the cache-hit run stay token-identical to the unchunked
        // cold decode.
        for bits in [0usize, 2] {
            let base = if bits == 0 {
                Arc::new(tiny_gqa(2).with_kv_page(2))
            } else {
                Arc::new(tiny_gqa(2).with_kv_format(KvFormat::bit_plane(bits)).with_kv_page(2))
            };
            let (_, mut lut) = quantized_engine_pair(base, 16);
            let req = Request { id: 0, prompt: vec![3, 7, 1, 12, 5, 9, 2, 11], max_new: 5 };
            let cold = lut.generate_batch(std::slice::from_ref(&req)).unwrap();
            lut.enable_prefix_cache();
            lut.configure_prefill(3, None);
            let publish = lut.generate_batch(std::slice::from_ref(&req)).unwrap();
            let warm = lut.generate_batch(std::slice::from_ref(&req)).unwrap();
            assert_eq!(publish[0].tokens, cold[0].tokens, "bits {bits}: chunked publish run");
            assert_eq!(warm[0].tokens, cold[0].tokens, "bits {bits}: chunked cache-hit run");
            let st = lut.prefix_cache().unwrap().stats();
            assert!(st.hits >= 1, "bits {bits}: warm run must hit: {st:?}");
            let arena = lut.arena().unwrap().stats();
            assert_eq!(arena.slots_in_use, 0, "bits {bits}: sessions must drain");
        }
    }

    #[test]
    fn chunked_prefill_budget_mixed_parity() {
        // A tight sweep budget interleaving a long chunked prefill with
        // live decodes must not change anyone's tokens — fairness
        // reorders work across sweeps, never the per-session math.
        let (_, mut lut) = quantized_engine_pair(tiny_gqa(2), 16);
        let mk = |id: u64, prompt: Vec<u32>, max_new: usize| GenRequest {
            id,
            prompt,
            params: SamplingParams { max_new, ..Default::default() },
            priority: 0,
        };
        let long: Vec<u32> = (0..16).map(|t| ((t * 3 + 1) % 20) as u32).collect();
        let batch = || {
            vec![mk(0, vec![1, 4], 8), mk(1, long.clone(), 5), mk(2, vec![7, 2, 9], 6)]
        };
        lut.configure_prefill(1, None);
        let baseline = serve_streams(&mut lut, batch(), 3);
        lut.configure_prefill(4, Some(6));
        let chunked = serve_streams(&mut lut, batch(), 3);
        for (i, (a, b)) in baseline.iter().zip(&chunked).enumerate() {
            assert_eq!(a.0, b.0, "request {i} tokens changed under budgeted chunking");
            assert_eq!(a.1, b.1, "request {i} finish reason");
        }
    }

    #[test]
    fn prefix_cache_native_engine_parity() {
        // Same bar through the native (per-session DecodeState) path.
        let model = Arc::new(tiny_gqa(2).with_kv_page(2));
        let mut e = Engine::new(EngineKind::Native(model)).unwrap();
        let req = Request { id: 0, prompt: vec![1, 4, 9, 2], max_new: 5 };
        let cold = e.generate_batch(std::slice::from_ref(&req)).unwrap();
        e.enable_prefix_cache();
        let _publish = e.generate_batch(std::slice::from_ref(&req)).unwrap();
        let warm = e.generate_batch(std::slice::from_ref(&req)).unwrap();
        assert_eq!(warm[0].tokens, cold[0].tokens, "native cache-hit run diverged");
        let st = e.prefix_cache().unwrap().stats();
        assert!(st.hits >= 1 && st.hit_tokens >= 3, "{st:?}");
    }

    #[test]
    fn prefix_cache_shared_prompts_batch_together() {
        // Several concurrent sessions sharing one published prefix must
        // batch in the fused sweep (each lane contributing the *same*
        // shared pages) and still match their solo decodes.
        let base = Arc::new(tiny_gqa(2).with_kv_format(KvFormat::bit_plane(2)).with_kv_page(2));
        let (_, mut lut) = quantized_engine_pair(base, 16);
        let mk = |id: u64, extra: &[u32]| {
            let mut prompt = vec![3, 7, 1, 12];
            prompt.extend_from_slice(extra);
            Request { id, prompt, max_new: 4 }
        };
        let batch = vec![mk(0, &[5]), mk(1, &[9, 2]), mk(2, &[11])];
        let solo: Vec<_> = batch
            .iter()
            .map(|r| lut.generate_batch(std::slice::from_ref(r)).unwrap().remove(0))
            .collect();
        lut.enable_prefix_cache();
        // Publish the shared stem as its own node (lookup follows full
        // edge matches only), then serve all three concurrently: every
        // warm lane borrows the same two stem pages.
        let stem = Request { id: 9, prompt: vec![3, 7, 1, 12], max_new: 1 };
        let _ = lut.generate_batch(std::slice::from_ref(&stem)).unwrap();
        let warm = lut.generate_batch(&batch).unwrap();
        for (i, (w, s)) in warm.iter().zip(&solo).enumerate() {
            assert_eq!(w.tokens, s.tokens, "shared-prefix lane {i} diverged");
        }
        let st = lut.prefix_cache().unwrap().stats();
        assert!(st.hits >= 3, "all warm lanes must hit: {st:?}");
    }

    #[test]
    fn quantized_kv_arena_reports_packed_bytes() {
        // The arena under a bit-plane format must physically allocate
        // (and report) the shrunken slots — ≥8× at W2 on head_dim 32
        // (at smaller head_dims the per-row f16 coefficients amortize
        // over fewer channels and the ratio drops; the bench models all
        // run head_dim 32).
        let f32_model = Arc::new(synthetic_model(
            &ModelConfig {
                vocab_size: 20,
                d_model: 64, // 2 heads × head_dim 32
                n_layers: 1,
                n_heads: 2,
                n_kv_heads: 2,
                d_ff: 48,
                max_seq: 16,
                kv_format: KvFormat::F32,
            },
            9,
        ));
        let q2 = Arc::new(f32_model.with_kv_format(KvFormat::bit_plane(2)));
        let (_, mut lut) = quantized_engine_pair(q2.clone(), 16);
        let _ = lut.generate_batch(&reqs(2)).unwrap();
        let stats = lut.arena().unwrap().stats();
        assert_eq!(stats.slot_bytes, q2.kv_bytes_per_session());
        assert!(
            f32_model.kv_bytes_per_session() >= 8 * stats.slot_bytes,
            "packed slot not ≥8× smaller: f32 {} vs {}",
            f32_model.kv_bytes_per_session(),
            stats.slot_bytes
        );
        assert_eq!(
            stats.bytes_resident % stats.slot_bytes,
            0,
            "slab bytes must be whole packed slots"
        );
    }

    #[test]
    fn capacity_exhaustion_parity() {
        // prompt + max_new beyond the KV capacity: both engines must
        // truncate at exactly the same point (capacity comes from the one
        // shared source, Model::decode_capacity).
        let model = Arc::new(synthetic_model(
            &ModelConfig {
                vocab_size: 20,
                d_model: 32,
                n_layers: 2,
                n_heads: 2,
                n_kv_heads: 2,
                d_ff: 48,
                max_seq: 8, // decode capacity 32
                kv_format: KvFormat::F32,
            },
            5,
        ));
        assert_eq!(model.decode_capacity(), 32);
        let (mut native, mut lut) = quantized_engine_pair(model, 16);
        let req = Request {
            id: 0,
            prompt: (0..30).map(|t| (t % 20) as u32).collect(),
            max_new: 10,
        };
        let a = native.generate_batch(std::slice::from_ref(&req)).unwrap();
        let b = lut.generate_batch(std::slice::from_ref(&req)).unwrap();
        assert_eq!(a[0].tokens, b[0].tokens, "truncation point diverged");
        assert!(!a[0].tokens.is_empty(), "should have generated something");
        assert!(a[0].tokens.len() < 10, "capacity must truncate generation");
    }

    #[test]
    fn arena_slot_reuse_keeps_decode_identical() {
        // Back-to-back batches on one engine reuse the same (dirty)
        // arena slots; results must be token-identical to the first
        // (zero-filled-slot) run — for native and LUT, MHA and GQA.
        for n_kv in [1usize, 4] {
            let (mut native, mut lut) = quantized_engine_pair(tiny_gqa(n_kv), 16);
            for engine in [&mut native, &mut lut] {
                let first = engine.generate_batch(&reqs(3)).unwrap();
                let second = engine.generate_batch(&reqs(3)).unwrap();
                for (a, b) in first.iter().zip(&second) {
                    assert_eq!(a.tokens, b.tokens, "n_kv {n_kv} {}", engine.kind_name());
                }
            }
        }
    }

    #[test]
    fn engines_share_one_arena_per_model() {
        // Both engines over the same base model draw slots from the
        // same pooled arena (its high-water mark sees both).
        let (mut native, mut lut) = quantized_engine_pair(tiny(), 16);
        let _ = native.generate_batch(&reqs(2)).unwrap();
        let _ = lut.generate_batch(&reqs(3)).unwrap();
        let a = native.arena().unwrap();
        let b = lut.arena().unwrap();
        assert!(Arc::ptr_eq(&a, &b), "one arena per model");
        assert!(a.stats().high_water >= 3);
        assert_eq!(a.stats().slots_in_use, 0, "all sessions released");
    }

    #[test]
    #[should_panic(expected = "KV arena exhausted")]
    fn arena_exhaustion_panics_like_capacity() {
        // A hard slot cap below the batch size fails loudly at session
        // creation — the arena-level analogue of "KV cache exhausted".
        let model = tiny();
        model.init_kv_arena(1, 1);
        let mut e = Engine::new(EngineKind::Native(model)).unwrap();
        let _ = e.generate_batch(&reqs(2));
    }

    #[test]
    fn empty_prompt_generates_nothing_strange() {
        let mut e = Engine::new(EngineKind::Native(tiny())).unwrap();
        let r = Request { id: 9, prompt: vec![], max_new: 3 };
        let rs = e.generate_batch(&[r]).unwrap();
        // no prompt → no logits to sample from → zero tokens is acceptable
        assert!(rs[0].tokens.len() <= 3);
    }

    #[test]
    fn pjrt_batch_matches_single_request() {
        // PJRT engine parity across batch sizes; exercises the hoisted
        // (once-per-serve-loop) executable load. Skips without the real
        // PJRT plugin or the AOT artifacts.
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        let artifact = dir.join("decode_step.hlo.txt");
        let ckpt = dir.join("tiny_small.tlm");
        if !artifact.exists() || !ckpt.exists() {
            eprintln!("[skip] pjrt artifacts missing (run `make artifacts`)");
            return;
        }
        let model = match TlmFile::load(&ckpt).and_then(|f| Model::from_tlm(&f)) {
            Ok(m) => Arc::new(m),
            Err(e) => {
                eprintln!("[skip] checkpoint unreadable: {e:#}");
                return;
            }
        };
        let kind = EngineKind::Pjrt { model, artifact, cache_len: 64 };
        let mut e = match Engine::new(kind) {
            Ok(e) => e,
            Err(err) => {
                eprintln!("[skip] PJRT plugin unavailable: {err:#}");
                return;
            }
        };
        let rs = e.generate_batch(&reqs(2)).unwrap();
        for (i, r) in reqs(2).iter().enumerate() {
            let single = e.generate_batch(std::slice::from_ref(r)).unwrap();
            assert_eq!(single[0].tokens, rs[i].tokens, "request {i}");
        }
    }
}
