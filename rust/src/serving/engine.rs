//! Decode engines: native fp32, LUT bit-plane, and PJRT (AOT artifact).
//!
//! All three implement the same continuous-batching `generate_batch`
//! contract so the router/batcher are engine-agnostic. Sessions within a
//! batch are stepped round-robin (one token each per sweep), which is the
//! scheduling shape of vLLM-style decode batching reduced to one thread.

use super::{Request, Response};
use crate::model::{argmax, rmsnorm, silu, softmax, DecodeState, Model, Rope};
use crate::quant::packing::BitPlanePacked;
use crate::runtime::{self, Runtime};
use crate::tensor::{dot, matvec, Matrix};
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

/// A model whose block linears are *packed bit-planes* — the paper's
/// deployment format. Non-linear parts (norms, embeddings, lm_head) stay
/// dense.
#[derive(Clone)]
pub struct LutModel {
    pub base: Arc<Model>,
    /// "l{layer}.{name}" → packed record for all 7 block linears.
    pub packed: Arc<HashMap<String, BitPlanePacked>>,
}

impl LutModel {
    pub fn new(base: Arc<Model>, packed: HashMap<String, BitPlanePacked>) -> Result<Self> {
        for l in 0..base.cfg.n_layers {
            for name in crate::model::BLOCK_LINEARS {
                anyhow::ensure!(
                    packed.contains_key(&format!("l{l}.{name}")),
                    "missing packed record l{l}.{name}"
                );
            }
        }
        Ok(Self { base, packed: Arc::new(packed) })
    }

}

/// Which decode path a worker runs.
#[derive(Clone)]
pub enum EngineKind {
    /// dense f32 matvecs over (dequantized or fp) weights
    Native(Arc<Model>),
    /// LUT-GEMV over packed bit-planes
    Lut(LutModel),
    /// PJRT execution of the AOT `decode_step.hlo.txt`
    Pjrt { model: Arc<Model>, artifact: PathBuf, cache_len: usize },
}

/// A decode engine (one per worker thread).
pub struct Engine {
    kind: EngineKind,
    runtime: Option<Runtime>,
}

impl Engine {
    pub fn new(kind: EngineKind) -> Result<Self> {
        let runtime = match &kind {
            EngineKind::Pjrt { .. } => Some(Runtime::cpu()?),
            _ => None,
        };
        Ok(Self { kind, runtime })
    }

    pub fn kind_name(&self) -> &'static str {
        match self.kind {
            EngineKind::Native(_) => "native",
            EngineKind::Lut(_) => "lut",
            EngineKind::Pjrt { .. } => "pjrt",
        }
    }

    /// Decode a batch of requests with round-robin continuous batching.
    pub fn generate_batch(&mut self, reqs: &[Request]) -> Result<Vec<Response>> {
        match &self.kind {
            EngineKind::Native(model) => {
                let model = model.clone();
                self.generate_generic(reqs, |_| NativeSession::new(&model))
            }
            EngineKind::Lut(lm) => {
                let lm = lm.clone();
                self.generate_generic(reqs, |_| LutSession::new(&lm))
            }
            EngineKind::Pjrt { model, artifact, cache_len } => {
                let (model, artifact, cache_len) = (model.clone(), artifact.clone(), *cache_len);
                let rt = self.runtime.as_mut().context("pjrt runtime")?;
                pjrt_generate(rt, &model, &artifact, cache_len, reqs)
            }
        }
    }

    fn generate_generic<S: Session>(
        &self,
        reqs: &[Request],
        mut make: impl FnMut(&Request) -> S,
    ) -> Result<Vec<Response>> {
        struct Active<S> {
            idx: usize,
            sess: S,
            prompt_left: std::vec::IntoIter<u32>,
            next_token: Option<u32>,
            out: Vec<u32>,
            started: Instant,
            first_tok: Option<Instant>,
        }
        let mut active: Vec<Active<S>> = reqs
            .iter()
            .enumerate()
            .map(|(idx, r)| Active {
                idx,
                sess: make(r),
                prompt_left: r.prompt.clone().into_iter(),
                next_token: None,
                out: Vec::new(),
                started: Instant::now(),
                first_tok: None,
            })
            .collect();
        let mut done: Vec<Option<Response>> = (0..reqs.len()).map(|_| None).collect();

        // Round-robin sweeps: each active session advances one token per
        // sweep (prompt prefill counts as steps too — single-token
        // engine).
        while !active.is_empty() {
            let mut still = Vec::with_capacity(active.len());
            for mut a in active {
                let capacity_left = a.sess.capacity() - a.sess.pos();
                let tok = a.next_token.take().or_else(|| a.prompt_left.next());
                let logits = match tok {
                    Some(t) if capacity_left > 0 => a.sess.step(t),
                    _ => {
                        // out of prompt+generation or capacity: finalize
                        finalize(&mut done, &a, reqs);
                        continue;
                    }
                };
                if a.prompt_left.len() == 0 {
                    // generating
                    if a.first_tok.is_none() {
                        a.first_tok = Some(Instant::now());
                    }
                    if a.out.len() < reqs[a.idx].max_new {
                        let next = argmax(&logits) as u32;
                        a.out.push(next);
                        a.next_token = Some(next);
                        still.push(a);
                    } else {
                        finalize(&mut done, &a, reqs);
                    }
                } else {
                    still.push(a);
                }
            }
            active = still;
        }

        fn finalize<S>(
            done: &mut [Option<Response>],
            a: &Active<S>,
            reqs: &[Request],
        ) {
            let total = a.started.elapsed().as_micros() as u64;
            let first = a
                .first_tok
                .map(|t| (t - a.started).as_micros() as u64)
                .unwrap_or(total);
            done[a.idx] = Some(Response {
                id: reqs[a.idx].id,
                tokens: {
                    // drop the trailing speculative token (pushed but not
                    // requested) if any — out is exactly what was sampled
                    a.out.clone()
                },
                first_token_us: first,
                total_us: total,
            });
        }

        Ok(done.into_iter().map(|d| d.expect("all finalized")).collect())
    }
}

/// One in-flight decode session.
trait Session {
    fn step(&mut self, token: u32) -> Vec<f32>;
    fn pos(&self) -> usize;
    fn capacity(&self) -> usize;
}

struct NativeSession<'m> {
    model: &'m Model,
    state: DecodeState,
}

impl<'m> NativeSession<'m> {
    fn new(model: &'m Model) -> Self {
        Self { model, state: model.decode_state() }
    }
}

impl Session for NativeSession<'_> {
    fn step(&mut self, token: u32) -> Vec<f32> {
        self.state.step(self.model, token)
    }
    fn pos(&self) -> usize {
        self.state.pos()
    }
    fn capacity(&self) -> usize {
        self.state.capacity()
    }
}

/// LUT decode session: same math as `DecodeState::step` with every block
/// linear replaced by a packed LUT-GEMV.
struct LutSession<'m> {
    lm: &'m LutModel,
    k: Vec<Matrix>,
    v: Vec<Matrix>,
    pos: usize,
    rope: Rope,
    cap: usize,
    scratch: crate::lut::LutScratch,
    // reusable step buffers (decode loop is allocation-free)
    q: Vec<f32>,
    kx: Vec<f32>,
    vx: Vec<f32>,
    proj: Vec<f32>,
    up: Vec<f32>,
    gate: Vec<f32>,
    mid: Vec<f32>,
    down: Vec<f32>,
    attn: Vec<f32>,
    scores: Vec<f32>,
    normed: Vec<f32>,
}

impl<'m> LutSession<'m> {
    fn new(lm: &'m LutModel) -> Self {
        let cfg = &lm.base.cfg;
        let cap = cfg.max_seq * 4;
        Self {
            lm,
            k: (0..cfg.n_layers).map(|_| Matrix::zeros(cap, cfg.d_model)).collect(),
            v: (0..cfg.n_layers).map(|_| Matrix::zeros(cap, cfg.d_model)).collect(),
            pos: 0,
            rope: Rope::new(cap, cfg.head_dim()),
            cap,
            scratch: crate::lut::LutScratch::default(),
            q: Vec::new(),
            kx: Vec::new(),
            vx: Vec::new(),
            proj: Vec::new(),
            up: Vec::new(),
            gate: Vec::new(),
            mid: Vec::new(),
            down: Vec::new(),
            attn: Vec::new(),
            scores: Vec::new(),
            normed: Vec::new(),
        }
    }

}

impl Session for LutSession<'_> {
    fn step(&mut self, token: u32) -> Vec<f32> {
        // Destructure so each buffer gets its own disjoint &mut borrow
        // next to the shared `lm` borrow (allocation-free decode loop).
        let LutSession {
            lm,
            k,
            v,
            pos,
            rope,
            cap,
            scratch,
            q,
            kx,
            vx,
            proj,
            up,
            gate,
            mid,
            down,
            attn,
            scores,
            normed,
        } = self;
        let model = &lm.base;
        let cfg = &model.cfg;
        let (d, nh, hd) = (cfg.d_model, cfg.n_heads, cfg.head_dim());
        let scale = 1.0 / (hd as f32).sqrt();
        let t = *pos;
        assert!(t < *cap, "KV cache exhausted");
        let lin = |l: usize, name: &str, x: &[f32], out: &mut Vec<f32>, scratch: &mut crate::lut::LutScratch| {
            let rec = &lm.packed[&format!("l{l}.{name}")];
            out.resize(rec.d_out, 0.0);
            crate::lut::lut_gemv(rec, x, out, scratch);
        };

        let id = (token as usize).min(cfg.vocab_size - 1);
        let mut h: Vec<f32> = model.embed.row(id).to_vec();
        normed.resize(d, 0.0);
        attn.resize(d, 0.0);
        scores.resize(t + 1, 0.0);

        for l in 0..cfg.n_layers {
            let lw = &model.layers[l];
            rmsnorm(&h, &lw.norm1, normed);
            lin(l, "wq", normed, q, scratch);
            lin(l, "wk", normed, kx, scratch);
            lin(l, "wv", normed, vx, scratch);
            for hh in 0..nh {
                rope.apply(&mut q[hh * hd..(hh + 1) * hd], t);
                rope.apply(&mut kx[hh * hd..(hh + 1) * hd], t);
            }
            k[l].row_mut(t).copy_from_slice(kx);
            v[l].row_mut(t).copy_from_slice(vx);

            attn.iter_mut().for_each(|a| *a = 0.0);
            for hh in 0..nh {
                let o0 = hh * hd;
                for u in 0..=t {
                    scores[u] = dot(&q[o0..o0 + hd], &k[l].row(u)[o0..o0 + hd]) * scale;
                }
                softmax(&mut scores[..=t]);
                for u in 0..=t {
                    let w = scores[u];
                    if w < 1e-9 {
                        continue;
                    }
                    let vrow = &v[l].row(u)[o0..o0 + hd];
                    for i in 0..hd {
                        attn[o0 + i] += w * vrow[i];
                    }
                }
            }
            lin(l, "wo", attn, proj, scratch);
            for (hi, p) in h.iter_mut().zip(proj.iter()) {
                *hi += p;
            }

            rmsnorm(&h, &lw.norm2, normed);
            lin(l, "w1", normed, up, scratch);
            lin(l, "w3", normed, gate, scratch);
            mid.resize(up.len(), 0.0);
            for ((m, &u), &g) in mid.iter_mut().zip(up.iter()).zip(gate.iter()) {
                *m = u * silu(g);
            }
            lin(l, "w2", mid, down, scratch);
            for (hi, dn) in h.iter_mut().zip(down.iter()) {
                *hi += dn;
            }
        }
        *pos += 1;
        let h_copy = h.clone();
        rmsnorm(&h_copy, &model.norm_f, &mut h);
        matvec(&model.lm_head, &h)
    }

    fn pos(&self) -> usize {
        self.pos
    }
    fn capacity(&self) -> usize {
        self.cap
    }
}

/// PJRT path: run requests sequentially through the AOT decode-step
/// executable, threading the KV cache literals.
fn pjrt_generate(
    rt: &mut Runtime,
    model: &Model,
    artifact: &std::path::Path,
    cache_len: usize,
    reqs: &[Request],
) -> Result<Vec<Response>> {
    let nl = model.cfg.n_layers;
    let d = model.cfg.d_model;
    let cache_elems = nl * cache_len * d;
    let mut out = Vec::with_capacity(reqs.len());

    for r in reqs {
        let started = Instant::now();
        let mut first_tok = None;
        let exe = rt.load(artifact)?;
        let zeros = vec![0.0f32; cache_elems];
        let mut klit = runtime::literal_f32(&zeros, &[nl as i64, cache_len as i64, d as i64])?;
        let mut vlit = runtime::literal_f32(&zeros, &[nl as i64, cache_len as i64, d as i64])?;
        let mut logits: Vec<f32> = Vec::new();
        let mut pos = 0usize;
        let budget = cache_len.saturating_sub(2);
        for &t in r.prompt.iter().take(budget) {
            let res = exe.run(&[
                runtime::literal_i32(t as i32),
                runtime::literal_i32(pos as i32),
                klit,
                vlit,
            ])?;
            let mut it = res.into_iter();
            logits = runtime::to_f32_vec(&it.next().context("logits")?)?;
            klit = it.next().context("kcache")?;
            vlit = it.next().context("vcache")?;
            pos += 1;
        }
        let mut tokens = Vec::with_capacity(r.max_new);
        for _ in 0..r.max_new {
            if pos >= cache_len {
                break;
            }
            let next = argmax(&logits) as u32;
            if first_tok.is_none() {
                first_tok = Some(started.elapsed().as_micros() as u64);
            }
            tokens.push(next);
            let res = exe.run(&[
                runtime::literal_i32(next as i32),
                runtime::literal_i32(pos as i32),
                klit,
                vlit,
            ])?;
            let mut it = res.into_iter();
            logits = runtime::to_f32_vec(&it.next().context("logits")?)?;
            klit = it.next().context("kcache")?;
            vlit = it.next().context("vcache")?;
            pos += 1;
        }
        let total = started.elapsed().as_micros() as u64;
        out.push(Response {
            id: r.id,
            tokens,
            first_token_us: first_tok.unwrap_or(total),
            total_us: total,
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{synthetic_model, ModelConfig};
    use crate::quant::{BpdqConfig, QuantMethod};

    fn tiny() -> Arc<Model> {
        Arc::new(synthetic_model(
            &ModelConfig { vocab_size: 20, d_model: 32, n_layers: 2, n_heads: 2, d_ff: 48, max_seq: 32 },
            3,
        ))
    }

    fn reqs(n: usize) -> Vec<Request> {
        (0..n)
            .map(|i| Request {
                id: i as u64,
                prompt: (0..5).map(|t| ((t + i) % 20) as u32).collect(),
                max_new: 4,
            })
            .collect()
    }

    #[test]
    fn native_engine_batch() {
        let mut e = Engine::new(EngineKind::Native(tiny())).unwrap();
        let rs = e.generate_batch(&reqs(3)).unwrap();
        assert_eq!(rs.len(), 3);
        for (i, r) in rs.iter().enumerate() {
            assert_eq!(r.id, i as u64);
            assert_eq!(r.tokens.len(), 4);
            assert!(r.first_token_us <= r.total_us);
        }
    }

    #[test]
    fn batch_matches_sequential() {
        // Continuous batching must not change results.
        let model = tiny();
        let mut e = Engine::new(EngineKind::Native(model.clone())).unwrap();
        let batch = e.generate_batch(&reqs(3)).unwrap();
        for (i, r) in reqs(3).iter().enumerate() {
            let single = e.generate_batch(std::slice::from_ref(r)).unwrap();
            assert_eq!(single[0].tokens, batch[i].tokens, "request {i}");
        }
    }

    #[test]
    fn lut_engine_matches_native_on_quantized_model() {
        // Quantize with BPDQ, then: native decode over dequantized weights
        // must equal LUT decode over the packed records.
        let model = tiny();
        let calib: Vec<Vec<u32>> =
            (0..4).map(|i| (0..20).map(|t| ((t * 3 + i) % 20) as u32).collect()).collect();
        let method = QuantMethod::Bpdq(BpdqConfig { k: 2, group_size: 16, iters: 2, gar: false, ..Default::default() });
        let qm = crate::model::pipeline::quantize_model(&model, &calib, &method).unwrap();

        let packed: HashMap<String, BitPlanePacked> = qm
            .packed
            .iter()
            .map(|(k, v)| (k.clone(), v.as_bit_planes().unwrap().clone()))
            .collect();
        let qmodel = Arc::new(qm.model.clone());
        let mut native = Engine::new(EngineKind::Native(qmodel.clone())).unwrap();
        let mut lut =
            Engine::new(EngineKind::Lut(LutModel::new(qmodel, packed).unwrap())).unwrap();

        let rs_native = native.generate_batch(&reqs(2)).unwrap();
        let rs_lut = lut.generate_batch(&reqs(2)).unwrap();
        for (a, b) in rs_native.iter().zip(&rs_lut) {
            assert_eq!(a.tokens, b.tokens);
        }
    }

    #[test]
    fn empty_prompt_generates_nothing_strange() {
        let mut e = Engine::new(EngineKind::Native(tiny())).unwrap();
        let r = Request { id: 9, prompt: vec![], max_new: 3 };
        let rs = e.generate_batch(&[r]).unwrap();
        // no prompt → no logits to sample from → zero tokens is acceptable
        assert!(rs[0].tokens.len() <= 3);
    }
}
