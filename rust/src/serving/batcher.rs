//! Dynamic batcher: collect requests into batches bounded by size and a
//! wait window (the standard latency/throughput dial of serving papers).

use super::{Request, Response};
use std::collections::VecDeque;
use std::sync::mpsc::Sender;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// A queued request plus its response channel.
pub struct Pending {
    pub request: Request,
    pub reply: Sender<Response>,
    pub enqueued: Instant,
}

struct QueueInner {
    items: VecDeque<Pending>,
    closed: bool,
}

/// MPMC-ish bounded wait queue feeding one worker.
#[derive(Clone)]
pub struct BatchQueue {
    inner: Arc<(Mutex<QueueInner>, Condvar)>,
    pub max_batch: usize,
    pub window: Duration,
}

impl BatchQueue {
    pub fn new(max_batch: usize, window: Duration) -> Self {
        assert!(max_batch >= 1);
        Self {
            inner: Arc::new((
                Mutex::new(QueueInner { items: VecDeque::new(), closed: false }),
                Condvar::new(),
            )),
            max_batch,
            window,
        }
    }

    pub fn push(&self, p: Pending) {
        let (m, cv) = &*self.inner;
        let mut q = m.lock().unwrap();
        q.items.push_back(p);
        cv.notify_one();
    }

    pub fn len(&self) -> usize {
        self.inner.0.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn close(&self) {
        let (m, cv) = &*self.inner;
        m.lock().unwrap().closed = true;
        cv.notify_all();
    }

    /// Block until at least one request is available (or closed), then
    /// collect up to `max_batch` requests arriving within `window`.
    /// Returns None when closed and drained.
    pub fn next_batch(&self) -> Option<Vec<Pending>> {
        let (m, cv) = &*self.inner;
        let mut q = m.lock().unwrap();
        loop {
            if !q.items.is_empty() {
                break;
            }
            if q.closed {
                return None;
            }
            q = cv.wait(q).unwrap();
        }
        // First request in hand: wait up to `window` for more.
        let deadline = Instant::now() + self.window;
        while q.items.len() < self.max_batch && !q.closed {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let (qq, timeout) = cv.wait_timeout(q, deadline - now).unwrap();
            q = qq;
            if timeout.timed_out() {
                break;
            }
        }
        let n = q.items.len().min(self.max_batch);
        Some(q.items.drain(..n).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;
    use std::thread;

    fn pending(id: u64) -> (Pending, std::sync::mpsc::Receiver<Response>) {
        let (tx, rx) = channel();
        (
            Pending {
                request: Request { id, prompt: vec![1], max_new: 1 },
                reply: tx,
                enqueued: Instant::now(),
            },
            rx,
        )
    }

    #[test]
    fn batches_respect_max_size() {
        let q = BatchQueue::new(2, Duration::from_millis(1));
        let mut rxs = Vec::new();
        for i in 0..5 {
            let (p, rx) = pending(i);
            q.push(p);
            rxs.push(rx);
        }
        let b1 = q.next_batch().unwrap();
        let b2 = q.next_batch().unwrap();
        let b3 = q.next_batch().unwrap();
        assert_eq!(b1.len(), 2);
        assert_eq!(b2.len(), 2);
        assert_eq!(b3.len(), 1);
        assert!(q.is_empty());
    }

    #[test]
    fn window_collects_late_arrivals() {
        let q = BatchQueue::new(8, Duration::from_millis(200));
        let (p, _rx) = pending(0);
        q.push(p);
        let q2 = q.clone();
        let h = thread::spawn(move || {
            thread::sleep(Duration::from_millis(30));
            let (p, rx) = pending(1);
            q2.push(p);
            rx
        });
        let batch = q.next_batch().unwrap();
        h.join().unwrap();
        assert_eq!(batch.len(), 2, "late arrival inside window should join");
    }

    #[test]
    fn close_unblocks() {
        let q = BatchQueue::new(4, Duration::from_millis(5));
        let q2 = q.clone();
        let h = thread::spawn(move || q2.next_batch());
        thread::sleep(Duration::from_millis(20));
        q.close();
        assert!(h.join().unwrap().is_none());
    }

    #[test]
    fn no_request_lost_or_duplicated() {
        let q = BatchQueue::new(3, Duration::from_millis(1));
        let n = 20;
        for i in 0..n {
            let (p, _rx) = pending(i);
            q.push(p);
        }
        let mut seen = Vec::new();
        while !q.is_empty() {
            for p in q.next_batch().unwrap() {
                seen.push(p.request.id);
            }
        }
        seen.sort_unstable();
        assert_eq!(seen, (0..n).collect::<Vec<_>>());
    }
}
