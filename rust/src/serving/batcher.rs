//! Admission queue feeding one worker's scheduler.
//!
//! The historical `BatchQueue` collected a whole batch behind a wait
//! window and handed it to the engine to run to completion. Under
//! iteration-level scheduling the window is gone: the [`SubmitQueue`]
//! is a priority-FIFO pool the scheduler drains **one request at a
//! time, at every sweep boundary** — blocking only when it has no
//! active sessions at all. Load accounting (`queued + in-flight`) lives
//! here too so the router's least-loaded strategy sees work the
//! scheduler has admitted but not yet finished.
//!
//! Failure is surfaced, never hung: [`SubmitQueue::close_with_error`]
//! drains every queued request with `Done{finish_reason: Error}`, and a
//! push to a closed queue is rejected with an immediate terminal event
//! instead of being stranded.

use super::{CancelHandle, FinishReason, GenEvent, GenRequest, Usage};
use std::collections::VecDeque;
use std::sync::mpsc::Sender;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

/// A queued request plus its event channel and cancellation flag.
pub struct Pending {
    pub request: GenRequest,
    pub events: Sender<GenEvent>,
    pub cancel: CancelHandle,
    pub enqueued: Instant,
}

impl Pending {
    /// Terminate this request without ever admitting it: emit the
    /// single `Done` event (no tokens were produced).
    pub(crate) fn reject(self, finish_reason: FinishReason, error: Option<String>) {
        let usage = Usage {
            prompt_tokens: self.request.prompt.len(),
            total_us: self.enqueued.elapsed().as_micros() as u64,
            ..Usage::default()
        };
        let _ = self.events.send(GenEvent::Done { finish_reason, usage, error });
    }
}

struct QueueInner {
    items: VecDeque<Pending>,
    closed: bool,
    /// Set by `close_with_error`: why this worker can no longer serve.
    error: Option<String>,
    /// Requests popped by the scheduler but not yet retired.
    in_flight: usize,
}

/// MPSC-ish wait queue feeding one worker's scheduler.
#[derive(Clone)]
pub struct SubmitQueue {
    inner: Arc<(Mutex<QueueInner>, Condvar)>,
}

impl Default for SubmitQueue {
    fn default() -> Self {
        Self::new()
    }
}

impl SubmitQueue {
    pub fn new() -> Self {
        Self {
            inner: Arc::new((
                Mutex::new(QueueInner {
                    items: VecDeque::new(),
                    closed: false,
                    error: None,
                    in_flight: 0,
                }),
                Condvar::new(),
            )),
        }
    }

    /// Enqueue a request. On a closed queue the request is rejected
    /// immediately — `Done{Error}` if the worker died with an error,
    /// `Done{Cancelled}` on normal shutdown — so callers always get a
    /// terminal event, never a hang.
    pub fn push(&self, p: Pending) {
        let (m, cv) = &*self.inner;
        let mut q = m.lock().unwrap();
        if q.closed {
            let err = q.error.clone();
            drop(q);
            match err {
                Some(e) => p.reject(FinishReason::Error, Some(e)),
                None => p.reject(FinishReason::Cancelled, None),
            }
            return;
        }
        q.items.push_back(p);
        cv.notify_one();
    }

    /// Pop the highest-priority request (FIFO within a priority), or
    /// `None` when the queue is empty *or* closed-and-drained. Never
    /// blocks — the scheduler uses this while it has active sessions.
    pub fn try_pop(&self) -> Option<Pending> {
        let (m, _) = &*self.inner;
        let mut q = m.lock().unwrap();
        Self::pop_best(&mut q)
    }

    /// Block until a request is available (returns it) or the queue is
    /// closed and drained (returns `None`). The scheduler uses this
    /// only when it has no active sessions.
    pub fn pop_blocking(&self) -> Option<Pending> {
        let (m, cv) = &*self.inner;
        let mut q = m.lock().unwrap();
        loop {
            if let Some(p) = Self::pop_best(&mut q) {
                return Some(p);
            }
            if q.closed {
                return None;
            }
            q = cv.wait(q).unwrap();
        }
    }

    fn pop_best(q: &mut QueueInner) -> Option<Pending> {
        if q.items.is_empty() {
            return None;
        }
        // Highest priority wins; the strict `>` keeps the earliest
        // submission within a priority level (FIFO fairness). O(n)
        // scan — admission is once per free slot per sweep, n is queue
        // depth.
        let mut best = 0usize;
        let mut best_pri = q.items[0].request.priority;
        for (i, p) in q.items.iter().enumerate().skip(1) {
            if p.request.priority > best_pri {
                best = i;
                best_pri = p.request.priority;
            }
        }
        let p = q.items.remove(best);
        if p.is_some() {
            q.in_flight += 1;
        }
        p
    }

    /// The scheduler retired one admitted request (any finish reason).
    pub fn finish_one(&self) {
        let (m, _) = &*self.inner;
        let mut q = m.lock().unwrap();
        q.in_flight = q.in_flight.saturating_sub(1);
    }

    /// Queued + admitted-but-unfinished requests — the router's
    /// least-loaded signal.
    pub fn load(&self) -> usize {
        let (m, _) = &*self.inner;
        let q = m.lock().unwrap();
        q.items.len() + q.in_flight
    }

    pub fn len(&self) -> usize {
        self.inner.0.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn is_closed(&self) -> bool {
        self.inner.0.lock().unwrap().closed
    }

    /// Graceful shutdown: queued requests still run to completion (the
    /// scheduler drains before its blocking pop returns `None`); only
    /// *new* submissions are rejected.
    pub fn close(&self) {
        let (m, cv) = &*self.inner;
        m.lock().unwrap().closed = true;
        cv.notify_all();
    }

    /// Fatal shutdown: the worker can no longer serve (engine init or
    /// sweep failure). Every queued request is rejected with
    /// `Done{finish_reason: Error, error}` now, and future pushes are
    /// rejected the same way.
    pub fn close_with_error(&self, error: &str) {
        let (m, cv) = &*self.inner;
        let drained: Vec<Pending> = {
            let mut q = m.lock().unwrap();
            q.closed = true;
            q.error = Some(error.to_string());
            q.items.drain(..).collect()
        };
        cv.notify_all();
        for p in drained {
            p.reject(FinishReason::Error, Some(error.to_string()));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serving::SamplingParams;
    use std::sync::mpsc::{channel, Receiver};
    use std::thread;
    use std::time::Duration;

    fn pending(id: u64, priority: u8) -> (Pending, Receiver<GenEvent>) {
        let (tx, rx) = channel();
        (
            Pending {
                request: GenRequest {
                    id,
                    prompt: vec![1],
                    params: SamplingParams { max_new: 1, ..Default::default() },
                    priority,
                },
                events: tx,
                cancel: CancelHandle::new(),
                enqueued: Instant::now(),
            },
            rx,
        )
    }

    #[test]
    fn fifo_within_priority() {
        let q = SubmitQueue::new();
        let mut rxs = Vec::new();
        for i in 0..5 {
            let (p, rx) = pending(i, 0);
            q.push(p);
            rxs.push(rx);
        }
        for i in 0..5 {
            assert_eq!(q.try_pop().unwrap().request.id, i);
        }
        assert!(q.try_pop().is_none());
        assert!(q.is_empty());
    }

    #[test]
    fn higher_priority_pops_first() {
        let q = SubmitQueue::new();
        for (id, pri) in [(0u64, 0u8), (1, 5), (2, 1), (3, 5)] {
            let (p, _rx) = pending(id, pri);
            q.push(p);
        }
        // priority 5 first (FIFO inside: 1 before 3), then 1, then 0.
        let order: Vec<u64> = (0..4).map(|_| q.try_pop().unwrap().request.id).collect();
        assert_eq!(order, vec![1, 3, 2, 0]);
    }

    #[test]
    fn load_counts_queued_and_in_flight() {
        let q = SubmitQueue::new();
        let (p, _rx) = pending(0, 0);
        q.push(p);
        let (p, _rx2) = pending(1, 0);
        q.push(p);
        assert_eq!(q.load(), 2);
        let _popped = q.try_pop().unwrap();
        assert_eq!(q.load(), 2, "admitted request still counts toward load");
        q.finish_one();
        assert_eq!(q.load(), 1);
    }

    #[test]
    fn close_unblocks_pop() {
        let q = SubmitQueue::new();
        let q2 = q.clone();
        let h = thread::spawn(move || q2.pop_blocking());
        thread::sleep(Duration::from_millis(20));
        q.close();
        assert!(h.join().unwrap().is_none());
    }

    #[test]
    fn close_drains_queued_before_none() {
        // Graceful close: already-queued work is still handed out.
        let q = SubmitQueue::new();
        let (p, _rx) = pending(7, 0);
        q.push(p);
        q.close();
        assert_eq!(q.pop_blocking().unwrap().request.id, 7);
        assert!(q.pop_blocking().is_none());
    }

    #[test]
    fn push_after_close_rejects_with_terminal_event() {
        let q = SubmitQueue::new();
        q.close();
        let (p, rx) = pending(3, 0);
        q.push(p);
        match rx.recv().unwrap() {
            GenEvent::Done { finish_reason, .. } => {
                assert_eq!(finish_reason, FinishReason::Cancelled)
            }
            other => panic!("expected Done, got {other:?}"),
        }
    }

    #[test]
    fn close_with_error_rejects_queued_and_future() {
        let q = SubmitQueue::new();
        let (p, rx_queued) = pending(1, 0);
        q.push(p);
        q.close_with_error("engine exploded");
        let (p, rx_late) = pending(2, 0);
        q.push(p);
        for rx in [rx_queued, rx_late] {
            match rx.recv().unwrap() {
                GenEvent::Done { finish_reason, error, .. } => {
                    assert_eq!(finish_reason, FinishReason::Error);
                    assert!(error.unwrap().contains("engine exploded"));
                }
                other => panic!("expected Done, got {other:?}"),
            }
        }
        assert!(q.pop_blocking().is_none());
    }

    #[test]
    fn no_request_lost_or_duplicated() {
        let q = SubmitQueue::new();
        let n = 20;
        let mut rxs = Vec::new();
        for i in 0..n {
            let (p, rx) = pending(i, (i % 3) as u8);
            q.push(p);
            rxs.push(rx);
        }
        let mut seen = Vec::new();
        while let Some(p) = q.try_pop() {
            seen.push(p.request.id);
            q.finish_one();
        }
        seen.sort_unstable();
        assert_eq!(seen, (0..n).collect::<Vec<_>>());
        assert_eq!(q.load(), 0);
    }
}
