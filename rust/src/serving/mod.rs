//! Serving stack — the L3 coordination layer.
//!
//! tokio is not in the offline vendor set, so the stack is built on
//! `std::thread` + channels, which also keeps it deterministic under
//! test:
//!
//! ```text
//! client ── submit_with ──► Router (round-robin / least-loaded)
//!     ▲                        │ per-worker SubmitQueue (priority FIFO)
//!     │ Receiver<GenEvent>     │
//!     │ + CancelHandle   ┌─────┴──────┐
//!     │              Worker 0 …   Worker N-1    (one Engine each)
//!     │                  │  Scheduler: one persistent decode sweep
//!     └──────────────────┤    · admit queued requests into free slots
//!        Token / Done    │      at every sweep boundary (≤ max_batch)
//!                        ▼    · step all sessions via the Stepper
//!              Stepper::step_batch     (native fp32 / LUT bit-plane /
//!                                       PJRT AOT artifact)
//! ```
//!
//! Scheduling is **iteration-level** (Orca / vLLM continuous batching):
//! the worker never collects a batch up-front and runs it to completion.
//! Instead one long-lived sweep loop admits queued requests into free
//! batch slots at each sweep boundary, advances every active session by
//! exactly one token, emits a [`GenEvent::Token`] per session as it is
//! produced, and retires finished / cancelled sessions immediately so
//! their KV-arena slots are re-admitted on the next iteration. A
//! 512-token request therefore no longer holds 8-token requests hostage:
//! short requests stream out and complete while long ones are still
//! decoding.
//!
//! The LUT engine is the paper's serving contribution: per-token decode
//! over *packed bit-planes* (no dequantized weight materialization), so
//! the memory-bound GEMV reads `k/16`-th of the fp16 bytes (Table 3).
//! All LUT sessions in a sweep are stepped **together** through a fused
//! pass (`lut_gemm`): each layer's packed plane words are gathered once
//! per step and applied to every active session's LUT, so per-token
//! decode cost falls toward `1/B` of the weight-fetch bound as the batch
//! fills. Every session's KV lives in a slot of the model's pooled
//! [`kv::KvArena`] (one slab per model), so the fused sweep's score/AV
//! phase runs as batched multi-session kernels over arena-adjacent
//! strips — in the arena's [`kv::KvFormat`]: f32 strips, or packed
//! bit-plane strips (`serve --kv-bits`) consumed by fused-dequant
//! kernels with quantization paid once at store time. The native engine
//! steps sessions independently — dense matvecs share nothing — but its
//! sessions draw from the same arena and the same scheduler loop.
//!
//! ## Serving API
//!
//! The streaming API is event-driven: a request is a [`GenRequest`]
//! (prompt + [`SamplingParams`] + priority) and its result is a stream
//! of [`GenEvent`]s on a per-request channel —
//! [`GenEvent::Token`]`{id, logprob}` per generated token, then exactly
//! one [`GenEvent::Done`]`{finish_reason, usage}`:
//!
//! ```ignore
//! let stream = router.submit_with(prompt, SamplingParams {
//!     temperature: 0.8, top_k: 40, seed: 7, max_new: 64,
//!     ..Default::default()
//! }, /*priority*/ 0);
//! let cancel = stream.cancel_handle();     // cancel.cancel() from anywhere
//! while let Some(ev) = stream.recv() {
//!     match ev {
//!         GenEvent::Token { id, logprob } => print_token(id, logprob),
//!         GenEvent::Done { finish_reason, usage, .. } => report(finish_reason, usage),
//!     }
//! }
//! ```
//!
//! * **Sampling** — `temperature == 0` is exactly `argmax` (token-
//!   identical to the historical greedy path, which all parity tests
//!   pin); `temperature > 0` samples from the temperature-scaled
//!   softmax through top-k / top-p truncation, seeded per request
//!   (`SamplingParams::seed`) so runs are reproducible.
//! * **Cancellation** — [`CancelHandle::cancel`] retires the session at
//!   the next sweep boundary: the KV-arena slot is released *before*
//!   the `Done{finish_reason: Cancelled}` event is sent, so observing
//!   `Done` guarantees the slot is free. Dropping the [`GenStream`]
//!   (receiver) cancels implicitly on the next emitted token.
//! * **Admission** — requests join a sweep already in flight whenever a
//!   batch slot is free (higher [`GenRequest::priority`] first, FIFO
//!   within a priority). Admission changes scheduling only, never
//!   tokens: a request admitted into a busy sweep at temp=0 decodes
//!   token-identically to running it solo.
//!
//! ### Migrating from `generate_batch`
//!
//! The historical batch-synchronous API survives as thin wrappers over
//! the event stream so callers can migrate incrementally:
//!
//! * [`Router::submit`]`(prompt, max_new)` returns a [`GenStream`];
//!   [`GenStream::collect`] blocks and folds the events into the legacy
//!   [`Response`] (`tokens`, `first_token_us`, `total_us`). Old code
//!   that did `let (_, rx) = router.submit(..); rx.recv()?` becomes
//!   `router.submit(..).collect()?`.
//! * [`Engine::generate_batch`]`(&[Request])` still decodes a fixed
//!   batch to completion and returns `Vec<Response>` — internally it
//!   now runs the same scheduler with `max_batch = batch.len()` over a
//!   pre-filled queue, so its temp=0 output is token-identical to the
//!   streaming path.
//!
//! Engine or worker failures are **surfaced, never hung**: a worker
//! whose engine fails to initialize (or whose sweep errors) closes its
//! queue with the error, every in-flight and queued request receives
//! `Done{finish_reason: Error, error: Some(msg)}`, and `collect()`
//! returns `Err` instead of blocking forever. A worker-thread *panic*
//! (e.g. KV-arena exhaustion during admission) closes the queue the
//! same way via a panic guard; requests already admitted at that
//! instant surface as a channel disconnect — `recv()` returns `None`,
//! `try_recv()` returns `Err(Disconnected)`, `collect()` returns
//! `Err` — still never a hang.
//!
//! ## Prefix cache
//!
//! Serving real traffic means serving a handful of hot system prompts
//! to millions of sessions. With the arena paged ([`kv::KvArena`]:
//! fixed-size position-block pages per (layer, K/V, kv-head) strip,
//! refcounted with copy-on-write), the stack shares that work through
//! an SGLang-style **radix prefix cache** ([`prefix::PrefixCache`],
//! `serve --prefix-cache`):
//!
//! * At **admission** the scheduler walks the radix tree over the
//!   request's prompt tokens; the matched prefix's pages are borrowed
//!   into the new session read-only, and only the cache-miss *suffix*
//!   is prefilled — cache-hit TTFT drops to near one sweep.
//! * At **prefill completion** the session publishes its prompt pages
//!   into the tree (refcount bumps, never byte copies; an edge splits
//!   when two prompts diverge inside it).
//! * A borrower's first **divergent store** copy-on-writes its own
//!   page; cached bytes are immutable while referenced. Decode is
//!   Markovian in (KV bytes, position, fed token) and shared pages
//!   travel bytewise — never re-quantized — so a cache-hit session
//!   decodes **token-identical** to a cold one at every `kv_bits`.
//! * Under pool pressure the arena calls the cache's LRU leaf evictor
//!   ([`kv::KvArena::set_reclaimer`]): cache memory yields to live
//!   sessions automatically, loudly panicking only when truly out.
//!
//! ## Chunked prefill
//!
//! Long prompts used to monopolize the sweep: prefill fed **one**
//! prompt token per sweep, so a 4k-token prompt held its batch slot
//! for 4k sweeps while every short request behind it paid the wait in
//! TTFT. `serve --prefill-chunk N` makes prefill multi-token and
//! budgeted, Sarathi-style:
//!
//! * **Budget semantics** — every sweep has a token budget
//!   (`--sweep-token-budget`, default `max_batch × prefill_chunk`).
//!   Decoding sessions claim 1 token each **first** (unconditionally —
//!   a sampled token must be fed), then prefilling sessions split what
//!   remains into chunks of up to `prefill_chunk` prompt tokens each,
//!   in admission order. A prefiller whose share is zero simply holds
//!   its slot until the next sweep.
//! * **Fairness both ways** — decode-first claiming means a long
//!   prompt can never stall token emission of running streams; the
//!   one-chunk-per-session-per-sweep cap means a decode-heavy batch
//!   can never starve prefill (and if nothing claimed the budget at
//!   all, the first prefiller is forced one token, so every sweep
//!   makes progress even at `--sweep-token-budget 0`).
//! * **One fused pass per chunk** — a chunk runs through
//!   `Stepper::step_prefill_chunk`: attention covers the
//!   arena-resident prefix plus the in-chunk causal block via the same
//!   page-run walk as decode, K/V for the whole chunk is stored in one
//!   pass (per-page packed-strip setup amortized per chunk, not per
//!   token), and only the final prompt token's logits are kept.
//!   Chunking is **token-identical** to one-token-per-sweep prefill at
//!   every `kv_bits` — the chunk kernels are the decode kernels at
//!   other lane counts, in the same accumulation order.
//! * **Prefix-cache interaction** — a cache hit leaves only the miss
//!   *suffix* to prefill, and that suffix is what gets chunked: the
//!   scheduler's prompt cursor is already past the borrowed prefix, so
//!   hit TTFT stays near one sweep and miss TTFT shrinks by the chunk
//!   factor. Publication still happens once, at suffix completion.
//!
//! ## Front door
//!
//! `serve --listen <addr>` ([`net::Server`]) exposes the stack over
//! plain HTTP/1.1, one request per connection (`Connection: close`):
//!
//! * `POST /v1/generate` — JSON body, streamed SSE response. The body
//!   carries `prompt` (string) **or** `tokens` (id array), plus any of
//!   `max_new`, `temperature`, `top_k`, `top_p`, `seed`, `stop` (id
//!   array), `priority` (0–255) or `tenant` (mapped to a priority via
//!   `--tenant-priority`). Token events are
//!   `event: token` / `data: {"id":N,"logprob":F}`; the single terminal
//!   event is `event: done` /
//!   `data: {"finish_reason":"length|stop|cancelled|error","usage":{…},"error":null|"msg"}`
//!   where `usage` carries `prompt_tokens`, `completion_tokens`,
//!   `queue_us`, `prefill_us`, `ttft_us`, `total_us`. Silent stretches
//!   emit `: keep-alive` comment frames.
//! * Errors are JSON bodies `{"error":"…"}` with the obvious statuses:
//!   `400` malformed/oversized-field body, `413`/`414`/`431` wire caps,
//!   `429` admission rejection (with a `Retry-After` header and
//!   `estimated_queue_delay_us`/`deadline_budget_us` in the body),
//!   `503` draining or connection pool full.
//! * **Admission control** (`--deadline-budget-us`): the front door
//!   estimates the request's wait as `Router::queue_depth × ITL p50`
//!   (floored at 50µs) **plus its own prefill cost**,
//!   `prompt_tokens / prefill_tokens_per_sec` (measured; the term is 0
//!   until the first prefill completes), and rejects `429` rather than
//!   queue past the budget — a 4k-token prompt no longer passes the
//!   same gate as a 10-token one.
//! * **Backpressure**: a client that disconnects (or stalls past the
//!   socket write timeout) fails its next frame write; the stream is
//!   cancelled, the scheduler retires the session at the next sweep
//!   boundary, and its KV-arena slot is released.
//! * **Drain**: `POST /admin/drain` (idempotent) flips reject-new;
//!   in-flight streams finish, then the accept loop exits and
//!   `serve --listen` prints the final summary and exits 0.
//! * `GET /healthz` — `200 {"status":"ok",…}`, or `503` with
//!   `"degraded"` (+ `worker_errors`) / `"draining"`.
//! * `GET /metrics` — the live [`LatencySummary`] JSON (arena, prefix
//!   cache, admission counters) plus the instantaneous `queue_depth`.
//! * Raw fallback: a connection whose first 4 bytes are `BPQ1` speaks
//!   length-prefixed frames (`u32-le len + JSON`) instead of HTTP — one
//!   request frame in, `{"type":"token"|"done"|"error",…}` frames out
//!   (`bpdq loadgen --raw`).
//!
//! ```text
//! curl -N -X POST http://127.0.0.1:8080/v1/generate \
//!      -H 'Content-Type: application/json' \
//!      -d '{"prompt":"2+2=","max_new":8,"tenant":"gold"}'
//! ```
//!
//! ## Static analysis
//!
//! The serving stack's performance and soundness invariants are
//! machine-checked by `bpdq lint` ([`crate::analysis`]), which runs in
//! CI and in `cargo test`. The contract is marker-driven:
//!
//! * `// lint: hot` on a `fn` opts it into rules **L2+L3+L4** — no
//!   heap allocation, no panic paths (`unwrap`/`expect`/`panic!`/hard
//!   asserts; `debug_assert*` is fine), no lock acquisition. Marked:
//!   the strip kernels ([`crate::tensor`]), the kvpack encode/decode
//!   path ([`crate::tensor::kvpack`]), the LUT-GEMM kernels
//!   ([`crate::lut`]), and the engine's `fused_attention` phase.
//!   Anything these functions need allocated or checked fallibly, the
//!   *caller* provides (scratch structs, resolved handles) — that is
//!   the shape the marker enforces.
//! * `// lint: sweep` opts into **L3+L4** only: the scheduler's
//!   `run_scheduler` loop may size per-sweep buffers but must never
//!   panic or take a lock mid-sweep (a panic strands every in-flight
//!   stream).
//! * Rules **L1** (every `unsafe` needs a `// SAFETY:` comment) and
//!   **L5** (raw-pointer calls only inside `unsafe` blocks, in files
//!   declaring an `//! aliasing:` protocol header) need no markers —
//!   they hold tree-wide, and in this stack all such code lives in
//!   [`kv`].
//!
//! Intentional exceptions carry a one-line justification in
//! `rust/lint.toml`; unused allowlist entries are reported so the file
//! cannot rot. The analysis is textual and per-function (it does not
//! chase calls) — reviews still own the call graph.

pub mod batcher;
pub mod engine;
pub mod kv;
pub mod metrics;
pub mod net;
pub mod prefix;
pub mod router;
pub(crate) mod scheduler;

pub use batcher::{Pending, SubmitQueue};
pub use engine::{Engine, EngineKind, LutModel};
pub use kv::{ArenaStats, KvArena, KvFormat, KvGeom, KvHandle, KvView, KvViewMut};
pub use metrics::{LatencySummary, Metrics};
pub use net::{Server, ServerConfig};
pub use prefix::{PrefixCache, PrefixStats};
pub use router::{GenStream, Router, RouterConfig, Strategy};

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// How a generation stream should sample its tokens. The default is
/// greedy decoding (`temperature == 0` ≡ `argmax`), which keeps every
/// token-identical parity guarantee of the historical API.
#[derive(Clone, Debug, PartialEq)]
pub struct SamplingParams {
    /// `0.0` = greedy argmax; `> 0` = sample from softmax(logits / T).
    pub temperature: f32,
    /// Keep only the `top_k` highest-probability tokens (`0` = off).
    pub top_k: usize,
    /// Nucleus sampling: keep the smallest prefix of the sorted
    /// distribution with cumulative probability ≥ `top_p` (`1.0` = off).
    pub top_p: f32,
    /// Per-request RNG seed — identical (seed, prompt, params) streams
    /// are token-identical regardless of batching.
    pub seed: u64,
    /// Generation stops (finish reason [`FinishReason::Stop`], stop
    /// token not emitted) when a sampled token is in this set.
    pub stop_tokens: Vec<u32>,
    /// Maximum number of generated tokens ([`FinishReason::Length`]).
    pub max_new: usize,
}

impl Default for SamplingParams {
    fn default() -> Self {
        Self {
            temperature: 0.0,
            top_k: 0,
            top_p: 1.0,
            seed: 0,
            stop_tokens: Vec::new(),
            max_new: 16,
        }
    }
}

/// A streaming generation request.
#[derive(Clone, Debug)]
pub struct GenRequest {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub params: SamplingParams,
    /// Admission priority: higher is admitted first, FIFO within a
    /// priority level.
    pub priority: u8,
}

/// Why a stream finished.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// `max_new` tokens generated, prompt exhausted with nothing to
    /// generate, or KV capacity reached.
    Length,
    /// A sampled token was in `stop_tokens`.
    Stop,
    /// Cancelled via [`CancelHandle`] (or the receiver was dropped).
    Cancelled,
    /// The engine failed; see the `error` field of [`GenEvent::Done`].
    Error,
}

/// Per-request accounting delivered with [`GenEvent::Done`]. All
/// timestamps are measured from submission (`enqueued`); when at least
/// one token was emitted, `queue_us ≤ ttft_us ≤ total_us`. A stream
/// that never emitted a token (cancelled during prefill, `max_new` 0,
/// prefill error) reports the `ttft_us: 0` sentinel, which is *below*
/// `queue_us` — check `completion_tokens > 0` before differencing
/// against `ttft_us`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Usage {
    pub prompt_tokens: usize,
    pub completion_tokens: usize,
    /// Submission → admission into a sweep.
    pub queue_us: u64,
    /// Prefill span: admission → last prompt token processed (0 if the
    /// stream retired before completing prefill). Unlike the other
    /// timestamps this is a *duration component* of TTFT, not an offset
    /// from submission: `queue_us + prefill_us ≤ ttft_us` when a token
    /// was emitted; the remainder is the first-decode span.
    pub prefill_us: u64,
    /// Submission → first emitted token (the real TTFT; 0 if no token
    /// was emitted).
    pub ttft_us: u64,
    /// Submission → `Done`.
    pub total_us: u64,
    /// The scheduler sweep at which the request retired — a clock-free
    /// observable for iteration-level scheduling tests.
    pub finished_sweep: u64,
}

/// One event on a generation stream: zero or more `Token`s, then
/// exactly one `Done`.
#[derive(Clone, Debug, PartialEq)]
pub enum GenEvent {
    Token {
        id: u32,
        /// Log-probability of `id` under the raw (untempered) softmax.
        logprob: f32,
    },
    Done {
        finish_reason: FinishReason,
        usage: Usage,
        /// `Some(message)` iff `finish_reason == Error`.
        error: Option<String>,
    },
}

/// Cancels a request from any thread. The scheduler observes the flag
/// at the next sweep boundary, releases the session's KV-arena slot,
/// and emits `Done{finish_reason: Cancelled}`.
#[derive(Clone, Debug, Default)]
pub struct CancelHandle {
    flag: Arc<AtomicBool>,
}

impl CancelHandle {
    pub fn new() -> Self {
        Self::default()
    }

    /// Request cancellation (idempotent, takes effect at the next sweep
    /// boundary — or immediately if the request is still queued).
    pub fn cancel(&self) {
        self.flag.store(true, Ordering::Release);
    }

    pub fn is_cancelled(&self) -> bool {
        self.flag.load(Ordering::Acquire)
    }
}

/// A generation request in the **legacy** batch-synchronous API (kept
/// for [`Engine::generate_batch`]); greedy-decodes `max_new` tokens.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub max_new: usize,
}

/// A completed generation in the legacy API — what
/// [`GenStream::collect`] folds the event stream into.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<u32>,
    /// Submission → first token event (real TTFT).
    pub first_token_us: u64,
    /// Submission → completion.
    pub total_us: u64,
}

/// Fold an event stream into the legacy [`Response`] shape, blocking
/// until `Done`. `Done{Error}` and channel disconnects become `Err` so
/// engine failures surface instead of hanging the caller.
pub(crate) fn collect_events(
    id: u64,
    events: &std::sync::mpsc::Receiver<GenEvent>,
) -> anyhow::Result<Response> {
    let mut tokens = Vec::new();
    loop {
        match events.recv() {
            Ok(GenEvent::Token { id: t, .. }) => tokens.push(t),
            Ok(GenEvent::Done { finish_reason, usage, error }) => {
                if finish_reason == FinishReason::Error {
                    anyhow::bail!(
                        "generation failed: {}",
                        error.unwrap_or_else(|| "engine error".into())
                    );
                }
                return Ok(Response {
                    id,
                    tokens,
                    first_token_us: usage.ttft_us,
                    total_us: usage.total_us,
                });
            }
            Err(_) => anyhow::bail!("worker disconnected before Done"),
        }
    }
}
