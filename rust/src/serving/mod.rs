//! Serving stack — the L3 coordination layer.
//!
//! tokio is not in the offline vendor set, so the stack is built on
//! `std::thread` + channels, which also keeps it deterministic under
//! test:
//!
//! ```text
//! client ── submit ──► Router (round-robin / least-loaded)
//!                         │ per-worker bounded queues
//!                  ┌──────┴──────┐
//!              Worker 0 …    Worker N-1      (one Engine each)
//!                  │   Batcher: collect ≤ max_batch within window
//!                  ▼
//!              Engine::generate_batch — continuous-batching decode
//!              (native fp32 / LUT bit-plane / PJRT AOT artifact)
//! ```
//!
//! The LUT engine is the paper's serving contribution: per-token decode
//! over *packed bit-planes* (no dequantized weight materialization), so
//! the memory-bound GEMV reads `k/16`-th of the fp16 bytes (Table 3).
//! Since the batched-decode refactor, all LUT sessions in a batch are
//! stepped **together** through a fused sweep (`lut_gemm`): each layer's
//! packed plane words are gathered once per step and applied to every
//! active session's LUT, so per-token decode cost falls toward `1/B` of
//! the weight-fetch bound as the batch fills. Every session's KV lives
//! in a slot of the model's pooled [`kv::KvArena`] (one slab per model),
//! so the fused sweep's score/AV phase runs as batched multi-session
//! kernels over arena-adjacent strips. The native engine keeps stepping
//! sessions independently — dense matvecs share nothing — but its
//! sessions draw from the same arena.

pub mod batcher;
pub mod engine;
pub mod kv;
pub mod metrics;
pub mod router;

pub use engine::{Engine, EngineKind, LutModel};
pub use kv::{ArenaStats, KvArena, KvGeom, KvHandle, KvView, KvViewMut};
pub use metrics::{LatencySummary, Metrics};
pub use router::{Router, RouterConfig, Strategy};

/// A generation request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub max_new: usize,
}

/// A completed generation.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<u32>,
    /// time from dequeue to first generated token
    pub first_token_us: u64,
    /// total decode time
    pub total_us: u64,
}
