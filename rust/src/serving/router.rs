//! Request router + worker pool — the vLLM-router-shaped front end.
//!
//! The [`Router`] owns N worker threads, each with its own
//! [`SubmitQueue`] and [`Engine`] running one persistent
//! iteration-level scheduler ([`Engine::serve`]). Requests are assigned
//! round-robin or least-loaded (queued + in-flight, since a worker's
//! sweep holds admitted requests that no longer sit in its queue);
//! events stream back on per-request channels so callers consume their
//! own tokens without a central dispatcher. Session lifecycle is
//! arena-backed: each admitted request's KV is a slot of the model's
//! pooled [`super::kv::KvArena`], claimed at admission and released the
//! moment the session retires — so slots recycle *within* a sweep, and
//! a capped arena only ever needs `max_batch` slots per worker.
//!
//! Failure is surfaced, never hung: a worker whose engine fails to
//! initialize — or whose sweep errors mid-flight — closes its queue
//! with the error. Queued and future requests on that queue receive
//! `Done{finish_reason: Error}` immediately, the error is recorded in
//! [`Router::worker_errors`], and the routing strategies skip closed
//! queues while any live worker remains.

use super::batcher::{Pending, SubmitQueue};
use super::engine::{Engine, EngineKind};
use super::metrics::Metrics;
use super::{CancelHandle, GenEvent, GenRequest, Response, SamplingParams};
use anyhow::Result;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Strategy {
    RoundRobin,
    LeastLoaded,
}

#[derive(Clone)]
pub struct RouterConfig {
    pub n_workers: usize,
    /// Batch slots per worker sweep — the scheduler admits up to this
    /// many concurrent sessions and back-fills retired slots at every
    /// sweep boundary.
    pub max_batch: usize,
    pub strategy: Strategy,
    /// Enable the per-worker radix prefix cache (`serve
    /// --prefix-cache`): repeated prompt prefixes are borrowed from
    /// refcounted KV pages instead of being re-prefilled. Off by
    /// default — caching holds pages resident between requests, which
    /// a memory-capped deployment may not want.
    pub prefix_cache: bool,
    /// Prompt tokens a prefilling session may claim per scheduler sweep
    /// (`serve --prefill-chunk`). 1 is the legacy one-token-per-sweep
    /// path; larger chunks amortize per-sweep overhead and cut TTFT by
    /// running one fused multi-token forward per chunk.
    pub prefill_chunk: usize,
    /// Per-sweep token budget shared by decode (1 token each, claimed
    /// first) and prefill chunks (`serve --sweep-token-budget`). `None`
    /// derives `max_batch × prefill_chunk`, which keeps chunk-of-one
    /// behavior identical to the unbudgeted scheduler.
    pub sweep_token_budget: Option<usize>,
}

impl Default for RouterConfig {
    fn default() -> Self {
        Self {
            n_workers: 2,
            max_batch: 8,
            strategy: Strategy::LeastLoaded,
            prefix_cache: false,
            prefill_chunk: 1,
            sweep_token_budget: None,
        }
    }
}

/// A live request: the per-token event receiver plus its cancel handle.
pub struct GenStream {
    pub id: u64,
    events: Receiver<GenEvent>,
    cancel: CancelHandle,
}

impl GenStream {
    pub(crate) fn new(id: u64, events: Receiver<GenEvent>, cancel: CancelHandle) -> Self {
        Self { id, events, cancel }
    }

    /// Next event, blocking. `None` means the worker died without a
    /// terminal event — possible only when its thread panicked
    /// mid-sweep (every non-panic failure path emits `Done{Error}`);
    /// treat it as end-of-stream.
    pub fn recv(&self) -> Option<GenEvent> {
        self.events.recv().ok()
    }

    /// Non-blocking variant of [`GenStream::recv`]. `Err(Empty)` means
    /// no event yet; `Err(Disconnected)` means the worker died without
    /// a terminal event (thread panic) — poll loops must stop on it,
    /// not retry.
    pub fn try_recv(&self) -> Result<GenEvent, std::sync::mpsc::TryRecvError> {
        self.events.try_recv()
    }

    /// Bounded-wait variant of [`GenStream::recv`]: `Err(Timeout)` means
    /// no event arrived within `timeout` (the stream is still live —
    /// retry), `Err(Disconnected)` means the worker died without a
    /// terminal event. The SSE pump uses this to interleave keep-alive
    /// frames with token events and to detect worker death without
    /// blocking a connection thread forever.
    pub fn recv_timeout(
        &self,
        timeout: std::time::Duration,
    ) -> Result<GenEvent, std::sync::mpsc::RecvTimeoutError> {
        self.events.recv_timeout(timeout)
    }

    /// Request cancellation: the scheduler retires the session (and
    /// releases its KV slot) at the next sweep boundary, then emits
    /// `Done{finish_reason: Cancelled}`.
    pub fn cancel(&self) {
        self.cancel.cancel();
    }

    /// A clonable handle for cancelling from another thread.
    pub fn cancel_handle(&self) -> CancelHandle {
        self.cancel.clone()
    }

    /// Legacy-shaped completion: block until `Done`, folding the token
    /// events into a [`Response`]. `Done{Error}` becomes `Err`.
    pub fn collect(self) -> Result<Response> {
        super::collect_events(self.id, &self.events)
    }
}

pub struct Router {
    queues: Vec<SubmitQueue>,
    workers: Mutex<Vec<JoinHandle<()>>>,
    rr_next: AtomicU64,
    strategy: Strategy,
    pub metrics: Metrics,
    next_id: AtomicU64,
    errors: Arc<Mutex<Vec<String>>>,
}

/// Closes a worker's queue with an error if the worker thread unwinds
/// (e.g. a "KV arena exhausted" panic during session creation) — a
/// panicking worker must reject its waiters like any other failure,
/// never strand them on an open queue.
struct CloseOnPanic {
    queue: SubmitQueue,
    errors: Arc<Mutex<Vec<String>>>,
    worker: usize,
}

impl Drop for CloseOnPanic {
    fn drop(&mut self) {
        if std::thread::panicking() {
            let msg = format!("worker {}: panicked (see stderr)", self.worker);
            self.errors.lock().unwrap().push(msg.clone());
            self.queue.close_with_error(&msg);
        }
    }
}

impl Router {
    /// Spawn the worker pool. `make_engine` builds one engine kind per
    /// worker (engines are not Sync; each worker owns its own). A
    /// factory or engine-init failure does **not** fail the pool: the
    /// dead worker's queue is closed with the error so anything routed
    /// there gets an immediate `Done{Error}` instead of hanging, and
    /// the error is readable via [`Router::worker_errors`].
    pub fn start(
        cfg: RouterConfig,
        make_engine: impl Fn(usize) -> Result<EngineKind>,
    ) -> Result<Self> {
        anyhow::ensure!(cfg.n_workers >= 1, "router needs at least one worker");
        let metrics = Metrics::new();
        let errors: Arc<Mutex<Vec<String>>> = Arc::new(Mutex::new(Vec::new()));
        let mut queues = Vec::new();
        let mut workers = Vec::new();
        for w in 0..cfg.n_workers {
            let queue = SubmitQueue::new();
            let kind = make_engine(w);
            let q = queue.clone();
            let m = metrics.clone();
            let errs = errors.clone();
            let max_batch = cfg.max_batch;
            let prefix_cache = cfg.prefix_cache;
            let prefill_chunk = cfg.prefill_chunk;
            let sweep_token_budget = cfg.sweep_token_budget;
            workers.push(std::thread::spawn(move || {
                let _guard =
                    CloseOnPanic { queue: q.clone(), errors: errs.clone(), worker: w };
                let mut engine = match kind.and_then(Engine::new) {
                    Ok(e) => e,
                    Err(e) => {
                        let msg = format!("worker {w}: engine init failed: {e:#}");
                        eprintln!("{msg}");
                        errs.lock().unwrap().push(msg.clone());
                        // Close the queue with the error: requests
                        // already routed here — and any routed later —
                        // get Done{Error} instead of hanging forever.
                        q.close_with_error(&msg);
                        return;
                    }
                };
                engine.attach_metrics(m);
                if prefix_cache {
                    engine.enable_prefix_cache();
                }
                engine.configure_prefill(prefill_chunk, sweep_token_budget);
                if let Err(e) = engine.serve(&q, max_batch) {
                    let msg = format!("worker {w}: serve loop failed: {e:#}");
                    eprintln!("{msg}");
                    errs.lock().unwrap().push(msg.clone());
                    q.close_with_error(&msg);
                }
            }));
            queues.push(queue);
        }
        Ok(Self {
            queues,
            workers: Mutex::new(workers),
            rr_next: AtomicU64::new(0),
            strategy: cfg.strategy,
            metrics,
            next_id: AtomicU64::new(1),
            errors,
        })
    }

    /// Errors from dead workers (engine init / sweep failures), in
    /// arrival order.
    pub fn worker_errors(&self) -> Vec<String> {
        self.errors.lock().unwrap().clone()
    }

    /// Number of worker threads this router was started with (live or
    /// dead — `worker_errors` distinguishes).
    pub fn n_workers(&self) -> usize {
        self.queues.len()
    }

    /// Total load across the pool: queued requests plus sessions
    /// in-flight inside sweeps. The front door's admission control
    /// multiplies this by the observed inter-token latency to estimate
    /// the queueing delay a new request would inherit.
    pub fn queue_depth(&self) -> usize {
        self.queues.iter().map(|q| q.load()).sum()
    }

    fn pick_worker(&self) -> usize {
        // Route around dead workers while any queue is still open; if
        // the whole pool is dead, any queue will do (the push is
        // rejected with the worker's error).
        let mut candidates: Vec<usize> =
            (0..self.queues.len()).filter(|&i| !self.queues[i].is_closed()).collect();
        if candidates.is_empty() {
            candidates = (0..self.queues.len()).collect();
        }
        match self.strategy {
            Strategy::RoundRobin => {
                candidates[(self.rr_next.fetch_add(1, Ordering::Relaxed) as usize)
                    % candidates.len()]
            }
            Strategy::LeastLoaded => {
                *candidates.iter().min_by_key(|&&i| self.queues[i].load()).unwrap()
            }
        }
    }

    /// Submit a streaming request with explicit sampling parameters and
    /// admission priority; returns the live event stream.
    pub fn submit_with(
        &self,
        prompt: Vec<u32>,
        params: SamplingParams,
        priority: u8,
    ) -> GenStream {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let w = self.pick_worker();
        let (tx, rx) = channel();
        let cancel = CancelHandle::new();
        self.queues[w].push(Pending {
            request: GenRequest { id, prompt, params, priority },
            events: tx,
            cancel: cancel.clone(),
            enqueued: Instant::now(),
        });
        GenStream::new(id, rx, cancel)
    }

    /// Greedy-decode convenience (legacy shape): default sampling
    /// params with the given `max_new`. `submit(..).collect()?` is the
    /// migration of the old `submit` + `rx.recv()?` pair.
    pub fn submit(&self, prompt: Vec<u32>, max_new: usize) -> GenStream {
        self.submit_with(prompt, SamplingParams { max_new, ..Default::default() }, 0)
    }

    /// Graceful shutdown: close every queue (queued requests still
    /// finish), then join the workers. Idempotent, and `&self` so the
    /// front door can drain a `Arc<Router>` shared with connection
    /// threads (a second call finds the handles already drained).
    pub fn shutdown(&self) {
        for q in &self.queues {
            q.close();
        }
        let handles: Vec<JoinHandle<()>> = std::mem::take(&mut *self.workers.lock().unwrap());
        for w in handles {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{synthetic_model, Model, ModelConfig};
    use crate::serving::KvFormat;
    use crate::serving::{FinishReason, Usage};
    use std::collections::HashSet;
    use std::time::Duration;

    fn tiny_model() -> Arc<Model> {
        Arc::new(synthetic_model(
            &ModelConfig {
                vocab_size: 16,
                d_model: 16,
                n_layers: 1,
                n_heads: 2,
                n_kv_heads: 2,
                d_ff: 24,
                max_seq: 32,
                kv_format: KvFormat::F32,
            },
            5,
        ))
    }

    fn engine_kind() -> EngineKind {
        EngineKind::Native(tiny_model())
    }

    /// Drain a stream into (tokens, finish_reason, usage).
    fn drain(s: &GenStream) -> (Vec<u32>, FinishReason, Usage) {
        let mut tokens = Vec::new();
        loop {
            match s.recv().expect("stream must end with Done") {
                GenEvent::Token { id, .. } => tokens.push(id),
                GenEvent::Done { finish_reason, usage, .. } => {
                    return (tokens, finish_reason, usage)
                }
            }
        }
    }

    #[test]
    fn serves_concurrent_requests() {
        let router = Router::start(
            RouterConfig { n_workers: 2, max_batch: 4, ..Default::default() },
            |_| Ok(engine_kind()),
        )
        .unwrap();
        let streams: Vec<_> =
            (0..10).map(|i| router.submit(vec![(i % 16) as u32, 1, 2], 3)).collect();
        let mut ids = HashSet::new();
        for s in streams {
            let id = s.id;
            let resp = s.collect().expect("response");
            assert_eq!(resp.id, id);
            assert_eq!(resp.tokens.len(), 3);
            assert!(resp.first_token_us <= resp.total_us);
            ids.insert(id);
        }
        assert_eq!(ids.len(), 10, "no response lost/duplicated");
        let summary = router.metrics.summary();
        assert_eq!(summary.completed, 10);
        assert_eq!(summary.tokens, 30);
        router.shutdown();
    }

    #[test]
    fn engine_init_failure_closes_queue_instead_of_hanging() {
        // Regression: a worker whose engine init fails used to return
        // without closing its queue — requests routed there were never
        // answered and recv() hung forever. Now every request gets
        // Done{Error} and the error is surfaced on the router.
        let router = Router::start(
            RouterConfig { n_workers: 2, max_batch: 4, ..Default::default() },
            |w| anyhow::bail!("synthetic init failure on worker {w}"),
        )
        .unwrap();
        for i in 0..4 {
            let s = router.submit(vec![i], 4);
            let err = s.collect().expect_err("init failure must surface, not hang");
            assert!(format!("{err:#}").contains("synthetic init failure"), "{err:#}");
        }
        // Both workers recorded their init error.
        let wait_start = Instant::now();
        while router.worker_errors().len() < 2 {
            assert!(wait_start.elapsed() < Duration::from_secs(5), "errors never surfaced");
            std::thread::sleep(Duration::from_millis(1));
        }
        for e in router.worker_errors() {
            assert!(e.contains("engine init failed"), "{e}");
        }
        router.shutdown(); // must not hang either
    }

    #[test]
    fn pjrt_failure_surfaces_as_error_events() {
        // With the offline stub, Engine::new(Pjrt) fails at client
        // creation (init path); with a real plugin it fails in the serve
        // loop on the missing artifact (sweep path). Either way the
        // caller sees an error, never a hang.
        let router = Router::start(
            RouterConfig { n_workers: 1, max_batch: 2, ..Default::default() },
            |_| {
                Ok(EngineKind::Pjrt {
                    model: tiny_model(),
                    artifact: std::path::PathBuf::from("definitely/not/a/real/artifact.hlo.txt"),
                    cache_len: 16,
                })
            },
        )
        .unwrap();
        let s = router.submit(vec![1, 2], 4);
        assert!(s.collect().is_err(), "pjrt failure must surface");
        router.shutdown();
    }

    #[test]
    fn dead_worker_is_routed_around() {
        let model = tiny_model();
        let router = Router::start(
            RouterConfig { n_workers: 2, max_batch: 4, ..Default::default() },
            move |w| {
                if w == 0 {
                    anyhow::bail!("worker 0 is broken");
                }
                Ok(EngineKind::Native(model.clone()))
            },
        )
        .unwrap();
        // Wait for worker 0's queue to close so routing must avoid it.
        let wait_start = Instant::now();
        while router.worker_errors().is_empty() {
            assert!(wait_start.elapsed() < Duration::from_secs(5), "error never surfaced");
            std::thread::sleep(Duration::from_millis(1));
        }
        for i in 0..6 {
            let resp = router.submit(vec![(i % 16) as u32, 2], 2).collect();
            assert!(resp.is_ok(), "live worker must absorb the traffic: {resp:?}");
        }
        router.shutdown();
    }

    #[test]
    fn worker_panic_rejects_waiters_instead_of_hanging() {
        // A capped arena makes Stepper::make panic ("KV arena
        // exhausted") when admission oversubscribes it. The worker's
        // panic guard must close the queue so every caller gets a
        // terminal event or a disconnect — never a hang.
        let model = tiny_model();
        model.init_kv_arena(1, 1);
        let model2 = model.clone();
        let router = Router::start(
            RouterConfig { n_workers: 1, max_batch: 2, ..Default::default() },
            move |_| Ok(EngineKind::Native(model2.clone())),
        )
        .unwrap();
        let streams: Vec<_> = (0..3).map(|i| router.submit(vec![i as u32, 1], 100)).collect();
        for (i, s) in streams.into_iter().enumerate() {
            assert!(s.collect().is_err(), "stream {i} must surface the worker panic");
        }
        let wait_start = Instant::now();
        while router.worker_errors().is_empty() {
            assert!(wait_start.elapsed() < Duration::from_secs(5), "panic never surfaced");
            std::thread::sleep(Duration::from_millis(1));
        }
        assert!(router.worker_errors().iter().any(|e| e.contains("panicked")));
        router.shutdown();
    }

    #[test]
    fn cancellation_mid_generation_releases_arena_pages() {
        // Satellite: cancelling mid-generation must release the KV slot
        // (slots_in_use back to 0) and free its pages with a generation
        // bump, so a stale page ref can never see the next tenant's KV.
        let model = tiny_model();
        let arena = model.kv_arena();
        // Probe: materialize a page, note its (id, generation), release
        // — the freed page must read as dead forever after.
        let mut probe = arena.acquire().unwrap();
        let row = vec![0.5f32; 16];
        {
            let mut v = arena.view_mut(&mut probe);
            v.store_k(0, 0, &row);
            v.store_v(0, 0, &row);
        }
        let probe_pages = probe.page_ids();
        assert!(!probe_pages.is_empty(), "stores must materialize pages");
        arena.release(probe);
        for &(id, gen) in &probe_pages {
            assert!(!arena.page_is_live(id, gen), "released page {id} must be dead");
        }

        let model2 = model.clone();
        let router = Router::start(
            RouterConfig { n_workers: 1, max_batch: 2, ..Default::default() },
            move |_| Ok(EngineKind::Native(model2.clone())),
        )
        .unwrap();
        // A long stream (capacity 128 ≫ prompt+max_new).
        let s = router.submit(vec![1, 2, 3], 100);
        // Cancel only once generation is demonstrably in flight.
        match s.recv().expect("first event") {
            GenEvent::Token { .. } => {}
            other => panic!("expected a token first, got {other:?}"),
        }
        s.cancel();
        let (tokens, fin, usage) = drain(&s);
        assert_eq!(fin, FinishReason::Cancelled);
        assert!(usage.completion_tokens >= 1 && usage.completion_tokens < 100);
        let _ = tokens;
        // Done{Cancelled} is sent *after* the slot release, so this is
        // race-free: nothing else is running on this router.
        let stats = arena.stats();
        assert_eq!(stats.slots_in_use, 0, "cancelled slot must be released");
        assert_eq!(stats.pages_in_use, 0, "cancelled session's pages must be freed");
        // Metrics observed the post-release arena state too.
        let m = router.metrics.summary();
        assert_eq!(m.arena_slots_in_use, 0);
        router.shutdown();
    }

    #[test]
    fn short_requests_overtake_long_one() {
        // Acceptance: with max_batch 4, one 64-token request and eight
        // 4-token requests submitted together — every short request
        // completes (strictly earlier sweep) while the long one is still
        // decoding, and slot reuse keeps the arena at ≤ max_batch slots.
        let model = tiny_model();
        let model2 = model.clone();
        let router = Router::start(
            RouterConfig { n_workers: 1, max_batch: 4, ..Default::default() },
            move |_| Ok(EngineKind::Native(model2.clone())),
        )
        .unwrap();
        let long = router.submit(vec![1, 2, 3], 64);
        let shorts: Vec<_> =
            (0..8).map(|i| router.submit(vec![(i % 16) as u32, 5], 4)).collect();
        let (long_tokens, long_fin, long_usage) = drain(&long);
        assert_eq!(long_tokens.len(), 64);
        assert_eq!(long_fin, FinishReason::Length);
        for (i, s) in shorts.iter().enumerate() {
            let (tokens, fin, usage) = drain(s);
            assert_eq!(tokens.len(), 4, "short {i}");
            assert_eq!(fin, FinishReason::Length, "short {i}");
            assert!(
                usage.finished_sweep < long_usage.finished_sweep,
                "short {i} (sweep {}) must complete while the long request \
                 (sweep {}) is still decoding",
                usage.finished_sweep,
                long_usage.finished_sweep
            );
        }
        // All 9 requests fit through 4 slots: no arena growth beyond
        // max_batch, every slot released at the end.
        let stats = model.kv_arena().stats();
        assert!(stats.high_water <= 4, "arena grew past max_batch: {}", stats.high_water);
        assert_eq!(stats.slots_in_use, 0);
        router.shutdown();
    }

    #[test]
    fn arena_stats_flow_through_router_metrics() {
        // Workers observe their engines' pooled-arena occupancy into the
        // shared metrics: after serving, the summary must show slots
        // were claimed (high-water ≥ 1), all released, and slab bytes
        // resident.
        let router = Router::start(
            RouterConfig { n_workers: 2, max_batch: 4, ..Default::default() },
            |_| Ok(engine_kind()),
        )
        .unwrap();
        let streams: Vec<_> =
            (0..6).map(|i| router.submit(vec![(i % 16) as u32, 2], 2)).collect();
        for s in streams {
            s.collect().unwrap();
        }
        let s = router.metrics.summary();
        assert!(s.arena_high_water >= 1, "arena saw sessions");
        assert_eq!(s.arena_slots_in_use, 0, "all slots released after serving");
        assert!(s.arena_bytes_resident > 0, "slab resident bytes reported");
        router.shutdown();
    }

    #[test]
    fn prefix_cache_config_wires_workers_and_keeps_tokens() {
        // `prefix_cache: true` enables the radix cache on every worker:
        // repeated prompts must hit it (visible in the live metrics
        // summary) and decode exactly as they do without it.
        let cold = Router::start(
            RouterConfig { n_workers: 1, max_batch: 2, ..Default::default() },
            |_| Ok(engine_kind()),
        )
        .unwrap();
        let baseline = cold.submit(vec![1, 2, 3, 4], 5).collect().unwrap();
        cold.shutdown();

        let router = Router::start(
            RouterConfig { n_workers: 1, max_batch: 2, prefix_cache: true, ..Default::default() },
            |_| Ok(engine_kind()),
        )
        .unwrap();
        for round in 0..3 {
            let resp = router.submit(vec![1, 2, 3, 4], 5).collect().unwrap();
            assert_eq!(resp.tokens, baseline.tokens, "round {round}: cache hit changed tokens");
        }
        let m = router.metrics.summary();
        assert!(m.prefix_lookups >= 3, "every admission consults the cache: {m:?}");
        assert!(m.prefix_hits >= 1, "repeated prompt must hit the cache: {m:?}");
        assert!(m.prefix_hit_tokens >= 3, "{m:?}");
        router.shutdown();
    }

    #[test]
    fn chunked_prefill_config_wires_workers_and_keeps_tokens() {
        // `prefill_chunk`/`sweep_token_budget` reach every worker's
        // engine: chunked prefill must decode token-identically to the
        // default one-token-per-sweep router and report prefill rate.
        let plain = Router::start(
            RouterConfig { n_workers: 1, max_batch: 2, ..Default::default() },
            |_| Ok(engine_kind()),
        )
        .unwrap();
        let baseline = plain.submit(vec![1, 2, 3, 4, 5, 6, 7], 5).collect().unwrap();
        plain.shutdown();

        let router = Router::start(
            RouterConfig {
                n_workers: 1,
                max_batch: 2,
                prefill_chunk: 3,
                sweep_token_budget: Some(6),
                ..Default::default()
            },
            |_| Ok(engine_kind()),
        )
        .unwrap();
        let resp = router.submit(vec![1, 2, 3, 4, 5, 6, 7], 5).collect().unwrap();
        assert_eq!(resp.tokens, baseline.tokens, "chunked prefill changed tokens");
        let m = router.metrics.summary();
        assert!(m.prefill_tokens_per_sec > 0.0, "chunked prefill must report a rate: {m:?}");
        router.shutdown();
    }

    #[test]
    fn streaming_metrics_populated() {
        let router = Router::start(
            RouterConfig { n_workers: 1, max_batch: 4, ..Default::default() },
            |_| Ok(engine_kind()),
        )
        .unwrap();
        let streams: Vec<_> = (0..4).map(|i| router.submit(vec![i as u32, 1], 6)).collect();
        for s in streams {
            s.collect().unwrap();
        }
        let m = router.metrics.summary();
        assert_eq!(m.completed, 4);
        assert_eq!(m.tokens, 24);
        assert!(m.decode_sweeps > 0);
        // Percentiles are order-consistent (values may legitimately be
        // 0 µs on a model this tiny — gaps can land within one tick).
        assert!(m.p95_first_us >= m.p50_first_us);
        assert!(m.p95_itl_us >= m.p50_itl_us);
        router.shutdown();
    }

    #[test]
    fn round_robin_distributes() {
        let router = Router::start(
            RouterConfig {
                n_workers: 3,
                strategy: Strategy::RoundRobin,
                max_batch: 1,
                ..Default::default()
            },
            |_| Ok(engine_kind()),
        )
        .unwrap();
        let streams: Vec<_> = (0..9).map(|_| router.submit(vec![1, 2], 1)).collect();
        for s in streams {
            s.collect().unwrap();
        }
        let s = router.metrics.summary();
        assert_eq!(s.completed, 9);
        router.shutdown();
    }

    #[test]
    fn zero_workers_is_rejected_at_start() {
        // pick_worker has no candidates with an empty pool — reject at
        // construction instead of panicking on the first submit.
        let res = Router::start(
            RouterConfig { n_workers: 0, max_batch: 2, ..Default::default() },
            |_| Ok(engine_kind()),
        );
        assert!(res.is_err());
    }

    #[test]
    fn shutdown_joins_cleanly() {
        let router = Router::start(RouterConfig::default(), |_| Ok(engine_kind())).unwrap();
        let s = router.submit(vec![1], 2);
        s.collect().unwrap();
        router.shutdown(); // must not hang
    }

    #[test]
    fn submit_after_shutdown_path_rejects() {
        // Closing the queues rejects later pushes with a terminal event
        // rather than stranding them (shutdown consumes the router, so
        // exercise via close()).
        let router = Router::start(
            RouterConfig { n_workers: 1, max_batch: 2, ..Default::default() },
            |_| Ok(engine_kind()),
        )
        .unwrap();
        router.queues[0].close();
        let s = router.submit(vec![1, 2], 3);
        match s.recv().expect("terminal event") {
            GenEvent::Done { finish_reason, .. } => {
                assert_eq!(finish_reason, FinishReason::Cancelled)
            }
            other => panic!("expected Done, got {other:?}"),
        }
        router.shutdown();
    }

    #[test]
    fn recv_timeout_times_out_delivers_and_disconnects() {
        use std::sync::mpsc::{channel, RecvTimeoutError};
        let (tx, rx) = channel();
        let s = GenStream::new(1, rx, CancelHandle::new());
        // Empty + sender alive: bounded wait, then Timeout.
        let t0 = Instant::now();
        assert_eq!(
            s.recv_timeout(Duration::from_millis(20)).unwrap_err(),
            RecvTimeoutError::Timeout
        );
        assert!(t0.elapsed() >= Duration::from_millis(20));
        // Queued event: delivered immediately.
        tx.send(GenEvent::Token { id: 7, logprob: -0.5 }).unwrap();
        match s.recv_timeout(Duration::from_secs(5)).expect("queued event") {
            GenEvent::Token { id, .. } => assert_eq!(id, 7),
            other => panic!("expected Token, got {other:?}"),
        }
        // Dropped sender (worker death): Disconnected, not a hang.
        drop(tx);
        assert_eq!(
            s.recv_timeout(Duration::from_secs(5)).unwrap_err(),
            RecvTimeoutError::Disconnected
        );
    }

    #[test]
    fn recv_timeout_on_live_router_sees_tokens() {
        let router = Router::start(
            RouterConfig { n_workers: 1, max_batch: 2, ..Default::default() },
            |_| Ok(engine_kind()),
        )
        .unwrap();
        let s = router.submit(vec![1, 2], 3);
        let mut tokens = 0;
        loop {
            match s.recv_timeout(Duration::from_secs(10)) {
                Ok(GenEvent::Token { .. }) => tokens += 1,
                Ok(GenEvent::Done { .. }) => break,
                Err(e) => panic!("stream died early: {e:?}"),
            }
        }
        assert_eq!(tokens, 3);
        router.shutdown();
    }

    #[test]
    fn queue_depth_counts_queued_and_in_flight() {
        let router = Router::start(
            RouterConfig { n_workers: 2, max_batch: 2, ..Default::default() },
            |_| Ok(engine_kind()),
        )
        .unwrap();
        assert_eq!(router.n_workers(), 2);
        assert_eq!(router.queue_depth(), 0, "idle router has no load");
        let streams: Vec<_> = (0..6).map(|i| router.submit(vec![i as u32, 1], 4)).collect();
        // Sampled while requests are queued/in flight, the depth must be
        // visible (submission itself bumps the queued count).
        for s in streams {
            s.collect().unwrap();
        }
        assert_eq!(router.queue_depth(), 0, "drained router has no load");
        router.shutdown();
    }

    #[test]
    fn shutdown_is_idempotent_via_shared_ref() {
        let router = Router::start(RouterConfig::default(), |_| Ok(engine_kind())).unwrap();
        let router = Arc::new(router);
        router.submit(vec![1], 2).collect().unwrap();
        router.shutdown();
        router.shutdown(); // second call must be a no-op, not a hang
    }
}
