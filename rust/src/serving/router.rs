//! Request router + worker pool — the vLLM-router-shaped front end.
//!
//! The [`Router`] owns N worker threads, each with its own
//! [`BatchQueue`] and [`Engine`]. Requests are assigned round-robin or
//! least-loaded; responses come back on per-request channels so callers
//! can await their own result without a central dispatcher. Session
//! lifecycle is arena-backed: each request's KV is a slot of the
//! model's pooled [`super::kv::KvArena`], claimed **up-front for every
//! request in a batch** when the engine builds its sessions (so a
//! capped arena must hold at least `max_batch` slots or batch
//! construction panics) and released back to the free list when the
//! session finalizes — the engines report per-arena occupancy into the
//! shared [`Metrics`] after every batch.

use super::batcher::{BatchQueue, Pending};
use super::engine::{Engine, EngineKind};
use super::metrics::Metrics;
use super::{Request, Response};
use anyhow::Result;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Strategy {
    RoundRobin,
    LeastLoaded,
}

#[derive(Clone)]
pub struct RouterConfig {
    pub n_workers: usize,
    pub max_batch: usize,
    pub batch_window: Duration,
    pub strategy: Strategy,
}

impl Default for RouterConfig {
    fn default() -> Self {
        Self {
            n_workers: 2,
            max_batch: 8,
            batch_window: Duration::from_millis(2),
            strategy: Strategy::LeastLoaded,
        }
    }
}

pub struct Router {
    queues: Vec<BatchQueue>,
    outstanding: Vec<Arc<AtomicUsize>>,
    workers: Vec<JoinHandle<()>>,
    rr_next: AtomicU64,
    strategy: Strategy,
    pub metrics: Metrics,
    next_id: AtomicU64,
}

impl Router {
    /// Spawn the worker pool. `make_engine` builds one engine per worker
    /// (engines are not Sync; each worker owns its own).
    pub fn start(
        cfg: RouterConfig,
        make_engine: impl Fn(usize) -> EngineKind,
    ) -> Result<Self> {
        let metrics = Metrics::new();
        let mut queues = Vec::new();
        let mut outstanding = Vec::new();
        let mut workers = Vec::new();
        for w in 0..cfg.n_workers {
            let queue = BatchQueue::new(cfg.max_batch, cfg.batch_window);
            let out_ctr = Arc::new(AtomicUsize::new(0));
            let kind = make_engine(w);
            let q = queue.clone();
            let ctr = out_ctr.clone();
            let m = metrics.clone();
            workers.push(std::thread::spawn(move || {
                let mut engine = match Engine::new(kind) {
                    Ok(e) => e,
                    Err(e) => {
                        eprintln!("worker {w}: engine init failed: {e:#}");
                        return;
                    }
                };
                // Engines report per-sweep decode batch occupancy into
                // the shared metrics (mean/max decode batch in summaries).
                engine.attach_metrics(m.clone());
                while let Some(batch) = q.next_batch() {
                    let reqs: Vec<Request> = batch.iter().map(|p| p.request.clone()).collect();
                    let t0 = Instant::now();
                    match engine.generate_batch(&reqs) {
                        Ok(responses) => {
                            for (p, r) in batch.into_iter().zip(responses) {
                                let queue_us = (t0 - p.enqueued).as_micros() as u64;
                                m.record(&r, queue_us, reqs.len());
                                let _ = p.reply.send(r);
                                ctr.fetch_sub(1, Ordering::Relaxed);
                            }
                        }
                        Err(e) => {
                            eprintln!("worker {w}: batch failed: {e:#}");
                            for p in batch {
                                ctr.fetch_sub(1, Ordering::Relaxed);
                                drop(p.reply); // closes the channel → caller sees error
                            }
                        }
                    }
                }
            }));
            queues.push(queue);
            outstanding.push(out_ctr);
        }
        Ok(Self {
            queues,
            outstanding,
            workers,
            rr_next: AtomicU64::new(0),
            strategy: cfg.strategy,
            metrics,
            next_id: AtomicU64::new(1),
        })
    }

    fn pick_worker(&self) -> usize {
        match self.strategy {
            Strategy::RoundRobin => {
                (self.rr_next.fetch_add(1, Ordering::Relaxed) as usize) % self.queues.len()
            }
            Strategy::LeastLoaded => {
                let mut best = 0;
                let mut best_load = usize::MAX;
                for (i, ctr) in self.outstanding.iter().enumerate() {
                    let load = ctr.load(Ordering::Relaxed) + self.queues[i].len();
                    if load < best_load {
                        best_load = load;
                        best = i;
                    }
                }
                best
            }
        }
    }

    /// Submit a request; returns the channel the response arrives on.
    pub fn submit(&self, prompt: Vec<u32>, max_new: usize) -> (u64, Receiver<Response>) {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let w = self.pick_worker();
        self.outstanding[w].fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = channel();
        self.queues[w].push(Pending {
            request: Request { id, prompt, max_new },
            reply: tx,
            enqueued: Instant::now(),
        });
        (id, rx)
    }

    /// Drain and join all workers.
    pub fn shutdown(self) {
        for q in &self.queues {
            q.close();
        }
        for w in self.workers {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{synthetic_model, ModelConfig};
    use std::collections::HashSet;

    fn engine_kind() -> EngineKind {
        EngineKind::Native(Arc::new(synthetic_model(
            &ModelConfig {
                vocab_size: 16,
                d_model: 16,
                n_layers: 1,
                n_heads: 2,
                n_kv_heads: 2,
                d_ff: 24,
                max_seq: 32,
            },
            5,
        )))
    }

    #[test]
    fn serves_concurrent_requests() {
        let router = Router::start(
            RouterConfig { n_workers: 2, max_batch: 4, ..Default::default() },
            |_| engine_kind(),
        )
        .unwrap();
        let rxs: Vec<_> = (0..10)
            .map(|i| router.submit(vec![(i % 16) as u32, 1, 2], 3))
            .collect();
        let mut ids = HashSet::new();
        for (id, rx) in rxs {
            let resp = rx.recv().expect("response");
            assert_eq!(resp.id, id);
            assert_eq!(resp.tokens.len(), 3);
            ids.insert(id);
        }
        assert_eq!(ids.len(), 10, "no response lost/duplicated");
        let summary = router.metrics.summary();
        assert_eq!(summary.completed, 10);
        router.shutdown();
    }

    #[test]
    fn arena_stats_flow_through_router_metrics() {
        // Workers observe their engines' pooled-arena occupancy into the
        // shared metrics: after serving, the summary must show slots
        // were claimed (high-water ≥ 1), all released, and slab bytes
        // resident.
        let router = Router::start(
            RouterConfig { n_workers: 2, max_batch: 4, ..Default::default() },
            |_| engine_kind(),
        )
        .unwrap();
        let rxs: Vec<_> = (0..6).map(|i| router.submit(vec![(i % 16) as u32, 2], 2)).collect();
        for (_, rx) in rxs {
            rx.recv().unwrap();
        }
        let s = router.metrics.summary();
        assert!(s.arena_high_water >= 1, "arena saw sessions");
        assert_eq!(s.arena_slots_in_use, 0, "all slots released after serving");
        assert!(s.arena_bytes_resident > 0, "slab resident bytes reported");
        router.shutdown();
    }

    #[test]
    fn round_robin_distributes() {
        let router = Router::start(
            RouterConfig {
                n_workers: 3,
                strategy: Strategy::RoundRobin,
                max_batch: 1,
                batch_window: Duration::from_millis(1),
            },
            |_| engine_kind(),
        )
        .unwrap();
        let rxs: Vec<_> = (0..9).map(|_| router.submit(vec![1, 2], 1)).collect();
        for (_, rx) in rxs {
            rx.recv().unwrap();
        }
        // all workers saw work: max batch 1 + RR ⇒ each of 3 workers got 3
        let s = router.metrics.summary();
        assert_eq!(s.completed, 9);
        router.shutdown();
    }

    #[test]
    fn shutdown_joins_cleanly() {
        let router = Router::start(RouterConfig::default(), |_| engine_kind()).unwrap();
        let (_, rx) = router.submit(vec![1], 2);
        rx.recv().unwrap();
        router.shutdown(); // must not hang
    }
}
