//! Evaluation harness — every metric the paper's tables report, on the
//! synthetic proxies (DESIGN.md §3):
//!
//! * [`perplexity`]      — WikiText-2 proxy (held-out corpus ppl);
//! * [`exact_match`]     — GSM8K/MATH500 proxy (few-shot arithmetic,
//!   greedy decode, exact answer match);
//! * [`choice_accuracy`] — ARC-C/BoolQ/HellaSwag/MMLU proxy (lm-eval
//!   loglikelihood scoring over answer options);
//! * [`longctx`]         — LongBench proxy (passkey retrieval / summary /
//!   classification at increasing context);
//! * [`outliers`]        — Table 3's activation statistics (DiagR P95,
//!   Cnt10, Δ vs fp16).

pub mod outliers;

use crate::data::tasks::{ArithTask, ChoiceTask, LongCtxTask};
use crate::data::Tokenizer;
use crate::model::{greedy_generate, Model};

/// Token-level perplexity over a set of documents (next-token
/// cross-entropy, natural log → exp).
pub fn perplexity(model: &Model, docs: &[Vec<u32>]) -> f64 {
    let mut nll = 0.0f64;
    let mut count = 0usize;
    for doc in docs {
        if doc.len() < 2 {
            continue;
        }
        let logits = model.forward_full(doc);
        for t in 0..doc.len() - 1 {
            let target = doc[t + 1] as usize;
            nll -= log_softmax_at(logits.row(t), target);
            count += 1;
        }
    }
    if count == 0 {
        return f64::NAN;
    }
    (nll / count as f64).exp()
}

/// log p(target | logits) with a numerically-stable log-sum-exp.
pub fn log_softmax_at(logits: &[f32], target: usize) -> f64 {
    let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
    let lse: f64 = logits.iter().map(|&x| ((x as f64) - max).exp()).sum::<f64>().ln() + max;
    logits[target] as f64 - lse
}

/// Total log-likelihood of `continuation` tokens given `prompt` tokens.
pub fn continuation_loglik(model: &Model, prompt: &[u32], continuation: &[u32]) -> f64 {
    let mut full = prompt.to_vec();
    full.extend_from_slice(continuation);
    let logits = model.forward_full(&full);
    let mut ll = 0.0f64;
    for (i, &tok) in continuation.iter().enumerate() {
        // token at absolute position prompt.len()+i is predicted by the
        // logits at position prompt.len()+i-1
        let pos = prompt.len() + i - 1;
        ll += log_softmax_at(logits.row(pos), tok as usize);
    }
    ll
}

/// Exact-match accuracy on generation tasks (the decoded text must start
/// with the expected answer string).
pub fn exact_match(model: &Model, tok: &Tokenizer, tasks: &[ArithTask]) -> f64 {
    if tasks.is_empty() {
        return 0.0;
    }
    let mut correct = 0usize;
    for t in tasks {
        let prompt = tok.encode(&t.prompt);
        let want = &t.answer;
        let out = greedy_generate(model, &prompt, want.len() + 2);
        let text = tok.decode(&out);
        if text.starts_with(want.as_str()) {
            correct += 1;
        }
    }
    correct as f64 / tasks.len() as f64
}

/// Likelihood-scored multiple-choice accuracy (lm-eval convention:
/// argmax over summed continuation log-probs).
///
/// Fast path: the prompt prefix is decoded **once** into a KV cache and
/// forked per choice (`DecodeState::fork`), so an N-choice task costs
/// `P + Σ|choice|` decode steps instead of `N·(P+|choice|)²`-style full
/// forwards — a ~4× win on the eval battery (EXPERIMENTS.md §Perf).
pub fn choice_accuracy(model: &Model, tok: &Tokenizer, tasks: &[ChoiceTask]) -> f64 {
    if tasks.is_empty() {
        return 0.0;
    }
    let mut correct = 0usize;
    for t in tasks {
        let prompt = tok.encode(&t.prompt);
        // shared prefix
        let mut st = model.decode_state();
        let mut prompt_logits = Vec::new();
        for &tk in &prompt {
            prompt_logits = st.step(model, tk);
        }
        let mut best = (f64::NEG_INFINITY, 0usize);
        for (ci, choice) in t.choices.iter().enumerate() {
            let cont = tok.encode(choice);
            let mut fork = st.fork();
            let mut logits = prompt_logits.clone();
            let mut ll = 0.0f64;
            for (i, &ct) in cont.iter().enumerate() {
                ll += log_softmax_at(&logits, ct as usize);
                if i + 1 < cont.len() {
                    logits = fork.step(model, ct);
                }
            }
            if ll > best.0 {
                best = (ll, ci);
            }
        }
        if best.1 == t.correct {
            correct += 1;
        }
    }
    correct as f64 / tasks.len() as f64
}

/// Long-context generation score: fraction of tasks whose greedy decode
/// starts with the expected answer.
pub fn longctx(model: &Model, tok: &Tokenizer, tasks: &[LongCtxTask]) -> f64 {
    if tasks.is_empty() {
        return 0.0;
    }
    let mut correct = 0usize;
    for t in tasks {
        let prompt = tok.encode(&t.prompt);
        let want = t.answer.trim_end_matches('.');
        let out = greedy_generate(model, &prompt, want.len() + 2);
        let text = tok.decode(&out);
        if text.starts_with(want) {
            correct += 1;
        }
    }
    correct as f64 / tasks.len() as f64
}

/// The full benchmark battery for one model — the columns of Table 1.
#[derive(Clone, Debug)]
pub struct BenchScores {
    pub ppl: f64,
    pub arith: f64,
    pub fact_choice: f64,
    pub bool_fact: f64,
    pub continuation: f64,
    pub classify: f64,
}

/// Evaluation workload sizes (kept model-agnostic so fp16 and quantized
/// models see identical tasks).
#[derive(Clone, Copy, Debug)]
pub struct EvalConfig {
    pub seed: u64,
    pub n_ppl_docs: usize,
    pub n_arith: usize,
    pub arith_shots: usize,
    pub n_choice: usize,
}

impl Default for EvalConfig {
    fn default() -> Self {
        Self { seed: 0xE7A1, n_ppl_docs: 64, n_arith: 64, arith_shots: 3, n_choice: 64 }
    }
}

/// Run the battery. `gen` must be the same corpus generator the model was
/// trained on (same world).
pub fn run_battery(
    model: &Model,
    gen: &crate::data::CorpusGen,
    tok: &Tokenizer,
    cfg: &EvalConfig,
) -> BenchScores {
    use crate::data::tasks;
    let docs = gen.token_docs(crate::data::Split::Eval, cfg.n_ppl_docs, tok);
    BenchScores {
        ppl: perplexity(model, &docs),
        arith: exact_match(model, tok, &tasks::gen_arith(cfg.seed, cfg.n_arith, cfg.arith_shots)),
        fact_choice: choice_accuracy(model, tok, &tasks::gen_fact_choice(gen, cfg.seed, cfg.n_choice)),
        bool_fact: choice_accuracy(model, tok, &tasks::gen_bool_fact(gen, cfg.seed, cfg.n_choice)),
        continuation: choice_accuracy(model, tok, &tasks::gen_continuation(gen, cfg.seed, cfg.n_choice)),
        classify: choice_accuracy(model, tok, &tasks::gen_classify(gen, cfg.seed, cfg.n_choice)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{CorpusConfig, CorpusGen};
    use crate::model::{synthetic_model, ModelConfig};
    use crate::serving::KvFormat;

    fn tiny() -> Model {
        synthetic_model(
            &ModelConfig {
                vocab_size: 68,
                d_model: 16,
                n_layers: 1,
                n_heads: 2,
                n_kv_heads: 2,
                d_ff: 24,
                max_seq: 64,
                kv_format: KvFormat::F32,
            },
            11,
        )
    }

    #[test]
    fn log_softmax_properties() {
        let logits = vec![1.0f32, 2.0, 3.0];
        let probs: f64 = (0..3).map(|t| log_softmax_at(&logits, t).exp()).sum();
        assert!((probs - 1.0).abs() < 1e-9);
        assert!(log_softmax_at(&logits, 2) > log_softmax_at(&logits, 0));
    }

    #[test]
    fn ppl_of_uniform_model_near_vocab_size() {
        // An untrained model's ppl should be around vocab_size (uniform),
        // certainly within a small factor.
        let m = tiny();
        let docs: Vec<Vec<u32>> = (0..4).map(|i| (0..30).map(|t| ((t * 5 + i) % 68) as u32).collect()).collect();
        let ppl = perplexity(&m, &docs);
        assert!(ppl > 5.0 && ppl < 800.0, "ppl={ppl}");
    }

    #[test]
    fn ppl_detects_damage() {
        // Randomizing the final norm should hurt ppl on average text.
        let m = tiny();
        let gen = CorpusGen::new(CorpusConfig::default());
        let tok = Tokenizer::new();
        let docs = gen.token_docs(crate::data::Split::Eval, 8, &tok);
        let base = perplexity(&m, &docs);
        let mut damaged = m.clone();
        for w in damaged.layers[0].wq.data_mut() {
            *w *= 10.0;
        }
        let worse = perplexity(&damaged, &docs);
        assert!(worse.is_finite());
        // not a strict guarantee for arbitrary damage, but ×10 on wq of a
        // 1-layer model reliably distorts
        assert!(worse > base * 0.5, "base {base} worse {worse}");
    }

    #[test]
    fn continuation_loglik_additive() {
        let m = tiny();
        let p = vec![1u32, 2, 3];
        let c = vec![4u32, 5];
        let ll = continuation_loglik(&m, &p, &c);
        assert!(ll < 0.0 && ll.is_finite());
        // longer continuation ⇒ lower total loglik (more tokens)
        let c2 = vec![4u32, 5, 6, 7];
        assert!(continuation_loglik(&m, &p, &c2) < ll);
    }

    #[test]
    fn fast_choice_path_matches_full_forward_scoring() {
        // The prefix-fork fast path must pick the same argmax as the
        // reference full-forward loglik scoring.
        let m = tiny();
        let tok = Tokenizer::new();
        let gen = CorpusGen::new(CorpusConfig::default());
        let tasks = crate::data::tasks::gen_fact_choice(&gen, 42, 12);
        // reference scoring
        let mut ref_correct = 0;
        for t in &tasks {
            let prompt = tok.encode(&t.prompt);
            let mut best = (f64::NEG_INFINITY, 0usize);
            for (ci, choice) in t.choices.iter().enumerate() {
                let cont = tok.encode(choice);
                let ll = continuation_loglik(&m, &prompt, &cont);
                if ll > best.0 {
                    best = (ll, ci);
                }
            }
            if best.1 == t.correct {
                ref_correct += 1;
            }
        }
        let fast = choice_accuracy(&m, &tok, &tasks);
        assert!(
            (fast - ref_correct as f64 / tasks.len() as f64).abs() < 1e-9,
            "fast {fast} vs ref {}",
            ref_correct as f64 / tasks.len() as f64
        );
    }

    #[test]
    fn battery_runs_on_untrained_model() {
        let m = tiny();
        let gen = CorpusGen::new(CorpusConfig::default());
        let tok = Tokenizer::new();
        let cfg = EvalConfig { n_ppl_docs: 6, n_arith: 4, n_choice: 8, ..Default::default() };
        let s = run_battery(&m, &gen, &tok, &cfg);
        assert!(s.ppl.is_finite());
        for acc in [s.arith, s.fact_choice, s.bool_fact, s.continuation, s.classify] {
            assert!((0.0..=1.0).contains(&acc));
        }
        // untrained model ≈ chance on 4-way choice; just sanity-bound it
        assert!(s.fact_choice <= 1.0);
    }
}
