//! Activation outlier statistics (paper Table 3, right half).
//!
//! For each block's attention input stream, compute per-channel RMS
//! activation magnitude over a probe set, then:
//!
//! * **DiagR** — max-to-median ratio per layer; reported as the 95th
//!   percentile across layers (outlier *intensity*);
//! * **Cnt10** — number of channels exceeding 10× the median, summed
//!   across layers (outlier *quantity*);
//! * **ΔDiagR / ΔCnt10** — relative change vs the fp16 model. The paper's
//!   finding: GPTQ-W2 suppresses outliers (ΔDiagR −33%), BPDQ preserves
//!   them (−5%), and preservation correlates with downstream quality.

use crate::model::{Capture, Model, Rope};
use crate::tensor::Matrix;

#[derive(Clone, Debug)]
pub struct OutlierStats {
    /// per-layer max/median channel-RMS ratios
    pub diag_ratios: Vec<f64>,
    /// P95 across layers
    pub diag_r_p95: f64,
    /// channels >10× median, summed across layers
    pub cnt10: usize,
}

impl OutlierStats {
    /// Relative deltas vs a baseline (fp16) stat set.
    pub fn delta_vs(&self, base: &OutlierStats) -> (f64, f64) {
        let dr = (self.diag_r_p95 - base.diag_r_p95) / base.diag_r_p95;
        let dc = (self.cnt10 as f64 - base.cnt10 as f64) / (base.cnt10 as f64).max(1.0);
        (dr, dc)
    }
}

/// Probe the model with token sequences and collect the outlier stats of
/// every block's attention-input stream.
pub fn activation_outliers(model: &Model, probes: &[Vec<u32>]) -> OutlierStats {
    let max_len = probes.iter().map(|p| p.len()).max().unwrap_or(1);
    let rope = Rope::new(max_len, model.cfg.head_dim());
    let mut diag_ratios = Vec::with_capacity(model.cfg.n_layers);
    let mut cnt10 = 0usize;

    let mut hiddens: Vec<Matrix> = probes.iter().map(|p| model.embed_tokens(p)).collect();
    for l in 0..model.cfg.n_layers {
        // channel sums of squares over all probe positions
        let d = model.cfg.d_model;
        let mut ss = vec![0.0f64; d];
        let mut n = 0usize;
        for h in &hiddens {
            let mut cap = Capture::default();
            let _ = model.block_forward(l, h, &rope, Some(&mut cap));
            let x = &cap.inputs["attn_in"];
            for r in 0..x.rows() {
                for (j, &v) in x.row(r).iter().enumerate() {
                    ss[j] += (v as f64) * (v as f64);
                }
            }
            n += x.rows();
        }
        let rms: Vec<f64> = ss.iter().map(|&s| (s / n.max(1) as f64).sqrt()).collect();
        let mut sorted = rms.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = sorted[sorted.len() / 2].max(1e-12);
        let max = sorted[sorted.len() - 1];
        diag_ratios.push(max / median);
        cnt10 += rms.iter().filter(|&&r| r > 10.0 * median).count();

        // advance hiddens
        for h in &mut hiddens {
            *h = model.block_forward(l, h, &rope, None);
        }
    }

    let mut sorted = diag_ratios.clone();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let p95_idx = ((sorted.len() as f64 * 0.95) as usize).min(sorted.len() - 1);
    OutlierStats { diag_r_p95: sorted[p95_idx], diag_ratios, cnt10 }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{synthetic_model, ModelConfig};
    use crate::serving::KvFormat;

    fn probes() -> Vec<Vec<u32>> {
        (0..4).map(|i| (0..20).map(|t| ((t * 3 + i) % 20) as u32).collect()).collect()
    }

    #[test]
    fn stats_shape_and_positivity() {
        let m = synthetic_model(
            &ModelConfig {
                vocab_size: 20,
                d_model: 32,
                n_layers: 3,
                n_heads: 2,
                n_kv_heads: 2,
                d_ff: 48,
                max_seq: 32,
                kv_format: KvFormat::F32,
            },
            3,
        );
        let s = activation_outliers(&m, &probes());
        assert_eq!(s.diag_ratios.len(), 3);
        assert!(s.diag_r_p95 >= 1.0);
        for &r in &s.diag_ratios {
            assert!(r >= 1.0 && r.is_finite());
        }
    }

    #[test]
    fn identical_model_zero_delta() {
        let m = synthetic_model(
            &ModelConfig {
                vocab_size: 20,
                d_model: 16,
                n_layers: 2,
                n_heads: 2,
                n_kv_heads: 2,
                d_ff: 24,
                max_seq: 32,
                kv_format: KvFormat::F32,
            },
            4,
        );
        let a = activation_outliers(&m, &probes());
        let b = activation_outliers(&m, &probes());
        let (dr, dc) = b.delta_vs(&a);
        assert!(dr.abs() < 1e-12 && dc.abs() < 1e-12);
    }

    #[test]
    fn destroying_weights_changes_stats() {
        let m = synthetic_model(
            &ModelConfig {
                vocab_size: 20,
                d_model: 32,
                n_layers: 2,
                n_heads: 2,
                n_kv_heads: 2,
                d_ff: 48,
                max_seq: 32,
                kv_format: KvFormat::F32,
            },
            5,
        );
        let base = activation_outliers(&m, &probes());
        let mut flat = m.clone();
        // flatten layer-0 outputs toward uniform: zero wo ⇒ attn stream of
        // layer 1 loses structure
        for w in flat.layers[0].wo.data_mut() {
            *w = 0.01;
        }
        let s = activation_outliers(&flat, &probes());
        let (dr, _) = s.delta_vs(&base);
        assert!(dr.abs() > 1e-6, "expected some change, got {dr}");
    }
}
