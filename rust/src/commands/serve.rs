//! `bpdq serve` — quantize a checkpoint, start the router/worker pool on
//! the chosen engine, push a synthetic request trace through it, and
//! report serving metrics. The W2-G256-on-one-GPU headline (§4.2) maps
//! to: quantize at W2-G256, report the exact packed size, and serve.
//!
//! Sampling flags (`--temperature --top-k --top-p --seed --stop`) feed
//! the per-request [`SamplingParams`]; the default (temperature 0) is
//! greedy and token-identical to the historical behavior. `--stream`
//! switches to the streaming smoke run: mixed `max_new` lengths through
//! one scheduler sweep plus a mid-run cancellation, with hard checks on
//! finish reasons, token counts, and arena-slot release — the CI gate
//! for the iteration-level scheduler path.

use anyhow::{Context, Result};
use bpdq::cli::Args;
use bpdq::data::{tasks, CorpusConfig, CorpusGen, Tokenizer};
use bpdq::model::pipeline::quantize_model;
use bpdq::model::{synthetic_model, Model, ModelConfig};
use bpdq::quant::{BpdqConfig, QuantMethod};
use bpdq::serving::{
    EngineKind, FinishReason, GenEvent, KvFormat, KvGeom, LutModel, Router, RouterConfig,
    SamplingParams, Server, ServerConfig, Strategy,
};
use std::collections::HashMap;
use std::sync::Arc;

use super::quantize::{calib_seqs, load_context, parse_method};

pub(crate) fn sampling_params(args: &Args, max_new: usize) -> Result<SamplingParams> {
    let stop_tokens: Vec<u32> = match args.get("stop") {
        None => Vec::new(),
        Some(spec) => spec
            .split(',')
            .filter(|t| !t.trim().is_empty())
            .map(|t| t.trim().parse::<u32>().with_context(|| format!("--stop: bad token `{t}`")))
            .collect::<Result<_>>()?,
    };
    Ok(SamplingParams {
        temperature: args.get_f64("temperature", 0.0).map_err(anyhow::Error::msg)? as f32,
        top_k: args.get_usize("top-k", 0).map_err(anyhow::Error::msg)?,
        top_p: args.get_f64("top-p", 1.0).map_err(anyhow::Error::msg)? as f32,
        seed: args.get_usize("seed", 0).map_err(anyhow::Error::msg)? as u64,
        stop_tokens,
        max_new,
    })
}

/// Everything the serving entrypoints share: the loaded (or synthetic)
/// model with its KV format applied, the quantized engine, and the
/// tokenizer — built from the same flags everywhere, so
/// `bpdq loadgen --verify-inprocess` can reconstruct the *identical*
/// engine a `serve --listen` process is running and compare wire tokens
/// against in-process decoding.
pub(crate) struct ServeSetup {
    pub kind: EngineKind,
    pub model: Arc<Model>,
    pub tok: Tokenizer,
    pub engine_name: String,
    pub prefix_cache: bool,
    /// `--prefill-chunk N`: prompt tokens a prefilling session may claim
    /// per scheduler sweep (1 = legacy one-token-per-sweep).
    pub prefill_chunk: usize,
    /// `--sweep-token-budget N`: per-sweep token budget shared by decode
    /// and prefill; absent derives `max_batch × prefill_chunk`.
    pub sweep_token_budget: Option<usize>,
}

pub(crate) fn build_setup(args: &Args) -> Result<ServeSetup> {
    // --simd {auto|scalar|avx2|neon}: pin the kernel dispatch tier.
    // Must run before anything touches a kernel — the tier latches on
    // first use. Unknown or host-unsupported tiers fail loudly here;
    // there is no silent fallback.
    if let Some(spec) = args.get("simd") {
        let tier = bpdq::tensor::SimdTier::parse(spec).map_err(anyhow::Error::msg)?;
        bpdq::tensor::simd::set_tier(tier).map_err(anyhow::Error::msg)?;
    }
    let model_path = args.get_or("model", "artifacts/tiny_small.tlm");
    let engine_name = args.get_or("engine", "lut");
    // --kv-bits {0|2|3|4}: 0 serves f32 KV (the historical layout);
    // 2..4 store the KV cache as packed bit-planes (BPDQ grid) and run
    // the fused-dequant attention kernels. Validated here, loudly.
    let kv_bits = args.get_usize("kv-bits", 0).map_err(anyhow::Error::msg)?;
    let kv_format = KvFormat::from_kv_bits(kv_bits)?;
    // --kv-page N: positions per arena page (the paging granularity of
    // slot growth, prefix sharing, and COW). --prefix-cache turns on
    // the per-worker radix prefix cache over those pages.
    let kv_page =
        args.get_usize("kv-page", bpdq::model::Model::DEFAULT_KV_PAGE).map_err(anyhow::Error::msg)?;
    anyhow::ensure!(kv_page >= 1, "--kv-page must be at least 1 position");
    let prefix_cache = args.has("prefix-cache");
    // --prefill-chunk N + --sweep-token-budget N: chunked prefill (see
    // the `## Chunked prefill` section of `bpdq::serving`). Chunk 1 is
    // the legacy path; the pjrt engine steps one token per sweep either
    // way (its stepper keeps the default chunk fallback).
    let prefill_chunk = args.get_usize("prefill-chunk", 1).map_err(anyhow::Error::msg)?;
    anyhow::ensure!(prefill_chunk >= 1, "--prefill-chunk must be at least 1 token");
    let sweep_token_budget = match args.get("sweep-token-budget") {
        Some(_) => {
            let n = args.get_usize("sweep-token-budget", 0).map_err(anyhow::Error::msg)?;
            anyhow::ensure!(n >= 1, "--sweep-token-budget must be at least 1 token");
            Some(n)
        }
        None => None,
    };
    anyhow::ensure!(
        !(engine_name == "pjrt" && prefix_cache),
        "--prefix-cache is not supported by the pjrt engine (its KV travels as literals, \
         not pooled arena pages) — drop the flag or use --engine lut|native"
    );
    // The PJRT engine threads its KV through f32 executable literals and
    // never touches the arena — a packed format would be silently
    // ignored, so refuse it instead of printing a misleading banner.
    anyhow::ensure!(
        !(engine_name == "pjrt" && kv_format.is_packed()),
        "--kv-bits {kv_bits} is not supported by the pjrt engine (its KV travels as f32 \
         literals) — drop the flag or use --engine lut|native"
    );
    // A missing checkpoint falls back to synthetic weights (same shape
    // as the trained tiny-LM) so the serving path — and the CI stream
    // smoke — runs without `make artifacts`. A *present but unreadable*
    // checkpoint still fails loudly.
    let (model, gen, tok) = if std::path::Path::new(model_path).exists() {
        load_context(model_path)?
    } else {
        let tok = Tokenizer::new();
        eprintln!("({model_path} not found — serving synthetic tiny-LM weights)");
        (
            synthetic_model(&ModelConfig::tiny_small(tok.vocab_size()), 7),
            CorpusGen::new(CorpusConfig::default()),
            tok,
        )
    };
    // Apply the KV format before anything touches the model's arena
    // (the arena's geometry is fixed at first use).
    let model = if kv_format == KvFormat::F32 { model } else { model.with_kv_format(kv_format) };
    let model = if kv_page == model.kv_page { model } else { model.with_kv_page(kv_page) };
    let model = Arc::new(model);
    println!(
        "kv cache: {} — {:.2} MiB/session ({} B/token){}",
        kv_format.label(),
        model.kv_bytes_per_session() as f64 / (1 << 20) as f64,
        model.kv_bytes_per_token(),
        if kv_format.is_packed() {
            // Geometry-only: no need to clone the model's weights just
            // to evaluate the f32 formula.
            let f32_bytes =
                KvGeom { format: KvFormat::F32, ..KvGeom::of(&model) }.slot_bytes();
            let ratio = f32_bytes as f64 / model.kv_bytes_per_session() as f64;
            format!(", {ratio:.1}x smaller than f32")
        } else {
            String::new()
        }
    );
    {
        let geom = KvGeom::of(&model);
        println!(
            "kv pages: {} positions/page, {} pages/slot ({} B/page), prefix cache {}",
            geom.page_positions,
            geom.pages_per_slot(),
            geom.page_bytes(),
            if prefix_cache { "on" } else { "off" }
        );
    }

    // Quantize (default BPDQ W2-G256 — the paper's extreme deployment
    // point) unless serving fp16 natively.
    let kind: EngineKind = match engine_name {
        "native-fp16" => EngineKind::Native(model.clone()),
        "pjrt" => {
            let artifact = std::path::PathBuf::from(
                args.get_or("artifact", "artifacts/decode_step.hlo.txt"),
            );
            anyhow::ensure!(artifact.exists(), "missing {}", artifact.display());
            let cache_len = args.get_usize("cache-len", 256).map_err(anyhow::Error::msg)?;
            EngineKind::Pjrt { model: model.clone(), artifact, cache_len }
        }
        "native" | "lut" => {
            let method = if args.has("method") {
                parse_method(args)?
            } else {
                QuantMethod::Bpdq(BpdqConfig { k: 2, group_size: 256, ..Default::default() })
            };
            let calib = calib_seqs(&gen, &tok, 48, model.cfg.max_seq);
            println!("quantizing with {} …", method.name());
            let qm = quantize_model(&model, &calib, &method)?;
            println!(
                "quantized: BPW {:.2}, packed size {:.2} MiB (fp16 {:.2} MiB)",
                qm.bits_per_weight(),
                qm.size_bytes() as f64 / (1 << 20) as f64,
                model.fp16_bytes() as f64 / (1 << 20) as f64
            );
            let qmodel = Arc::new(qm.model.clone());
            if engine_name == "lut" {
                let packed: HashMap<_, _> = qm
                    .packed
                    .iter()
                    .map(|(k, v)| {
                        (
                            k.clone(),
                            v.as_bit_planes()
                                .expect("BPDQ/BCQ packing required for the LUT engine")
                                .clone(),
                        )
                    })
                    .collect();
                EngineKind::Lut(LutModel::new(qmodel, packed)?)
            } else {
                EngineKind::Native(qmodel)
            }
        }
        other => anyhow::bail!("unknown engine `{other}` (native|native-fp16|lut|pjrt)"),
    };
    Ok(ServeSetup {
        kind,
        model,
        tok,
        engine_name: engine_name.to_string(),
        prefix_cache,
        prefill_chunk,
        sweep_token_budget,
    })
}

pub fn run(args: &Args) -> Result<()> {
    let ServeSetup {
        kind,
        model,
        tok,
        engine_name,
        prefix_cache,
        prefill_chunk,
        sweep_token_budget,
    } = build_setup(args)?;
    let n_requests = args.get_usize("requests", 24).map_err(anyhow::Error::msg)?;
    let n_workers = args.get_usize("workers", 2).map_err(anyhow::Error::msg)?;
    let max_new = args.get_usize("max-new", 8).map_err(anyhow::Error::msg)?;
    let max_batch = args.get_usize("max-batch", 8).map_err(anyhow::Error::msg)?;
    let params = sampling_params(args, max_new)?;
    let capacity = model.decode_capacity();

    println!("simd kernels: {}", bpdq::tensor::simd::active().label());
    println!(
        "starting router: {n_workers} workers, engine={engine_name}, max_batch={max_batch}, \
         prefill chunk {prefill_chunk}, sweep budget {}",
        match sweep_token_budget {
            Some(b) => b.to_string(),
            None => format!("{} (derived)", max_batch.max(1) * prefill_chunk),
        }
    );
    let router = Router::start(
        RouterConfig {
            n_workers,
            max_batch,
            strategy: Strategy::LeastLoaded,
            prefix_cache,
            prefill_chunk,
            sweep_token_budget,
        },
        |_| Ok(kind.clone()),
    )?;

    // --listen: hand the router to the network front door and block
    // until a drain completes (see `serving::net`). The trace/stream
    // smoke paths below stay in-process.
    if let Some(addr) = args.get("listen") {
        return run_listen(args, addr, router, tok, &model, prefix_cache, params);
    }

    if args.has("stream") {
        stream_smoke(&router, &tok, &params, n_requests, max_new, capacity)?;
        if prefix_cache {
            // Cache-off reference router over the same engine kind (and
            // the same pooled arena): the warm router's outputs must be
            // token-identical to this cold path.
            let cold = Router::start(
                RouterConfig {
                    n_workers: 1,
                    max_batch,
                    strategy: Strategy::LeastLoaded,
                    prefill_chunk,
                    sweep_token_budget,
                    ..Default::default()
                },
                |_| Ok(kind.clone()),
            )?;
            let res = prefix_smoke(&router, &cold, &tok, &params);
            cold.shutdown();
            res?;
        }
        if prefill_chunk > 1 {
            // Chunking-off reference router (chunk 1, no cache): the
            // chunked router's outputs must be token-identical to the
            // one-token-per-sweep path under a mixed long/short load.
            let reference = Router::start(
                RouterConfig { n_workers: 1, max_batch, ..Default::default() },
                |_| Ok(kind.clone()),
            )?;
            let res = chunked_smoke(&router, &reference, &tok, &params, max_new, capacity);
            reference.shutdown();
            res?;
        }
        print_summary(&router);
        router.shutdown();
        return Ok(());
    }

    // Request trace: few-shot arithmetic prompts (the interactive-decode
    // workload of Table 3).
    let trace = tasks::gen_arith(0xC0FFEE, n_requests, 2);
    let streams: Vec<_> = trace
        .iter()
        .map(|t| router.submit_with(tok.encode(&t.prompt), params.clone(), 0))
        .collect();
    let mut correct = 0usize;
    for (s, t) in streams.into_iter().zip(&trace) {
        let resp = s.collect()?;
        let text = tok.decode(&resp.tokens);
        if text.starts_with(t.answer.as_str()) {
            correct += 1;
        }
    }
    println!("\n--- serving report ---");
    println!(
        "exact-match        : {:.1}%",
        100.0 * correct as f64 / trace.len() as f64
    );
    print_summary(&router);
    router.shutdown();
    Ok(())
}

/// Streaming smoke: one long request and `n_requests - 1` short ones
/// with mixed `max_new`, all submitted together; the long one is
/// cancelled after its first token. Verifies iteration-level
/// scheduling end-to-end: shorts complete with their exact budgets
/// while the long one dies mid-decode, and every arena slot is
/// released. Errors (non-zero exit) on any violation — this is the CI
/// gate for the scheduler path.
fn stream_smoke(
    router: &Router,
    tok: &Tokenizer,
    params: &SamplingParams,
    n_requests: usize,
    max_new: usize,
    capacity: usize,
) -> Result<()> {
    let n_requests = n_requests.max(3);
    let trace = tasks::gen_arith(0xC0FFEE, n_requests, 2);
    // The long request gets a budget big enough that the mid-run cancel
    // always lands while it is still decoding.
    let long_budget = 256.min(capacity.saturating_sub(64)).max(max_new * 8);
    let mut budgets = Vec::with_capacity(n_requests);
    let mut streams = Vec::with_capacity(n_requests);
    for (i, t) in trace.iter().enumerate() {
        let mut p = params.clone();
        // Mixed lengths: one long stream, shorts jittered around max_new.
        p.max_new = if i == 0 { long_budget } else { max_new + (i % 3) };
        budgets.push(p.max_new);
        streams.push(router.submit_with(tok.encode(&t.prompt), p, 0));
    }
    println!(
        "stream smoke: {n_requests} requests (long budget {long_budget}, shorts ~{max_new}), \
         cancelling the long one after its first token"
    );

    // Cancel the long stream once generation is demonstrably in flight.
    match streams[0].recv() {
        Some(GenEvent::Token { .. }) => {}
        other => anyhow::bail!("long stream: expected a first token event, got {other:?}"),
    }
    streams[0].cancel();

    let greedy_run = params.temperature <= 0.0 && params.stop_tokens.is_empty();
    for (i, s) in streams.iter().enumerate() {
        let mut n_tokens = if i == 0 { 1 } else { 0 }; // long's first token already consumed
        let (finish, usage) = loop {
            match s.recv() {
                Some(GenEvent::Token { .. }) => n_tokens += 1,
                Some(GenEvent::Done { finish_reason, usage, error }) => {
                    if let Some(e) = error {
                        anyhow::bail!("stream {i}: engine error: {e}");
                    }
                    break (finish_reason, usage);
                }
                None => anyhow::bail!("stream {i}: worker disconnected before Done"),
            }
        };
        println!(
            "  stream {i:>2}: {n_tokens:>3} tokens, {finish:?} at sweep {}, \
             ttft {:.2} ms, total {:.2} ms",
            usage.finished_sweep,
            usage.ttft_us as f64 / 1e3,
            usage.total_us as f64 / 1e3,
        );
        if i == 0 {
            anyhow::ensure!(
                finish == FinishReason::Cancelled,
                "long stream must be cancelled mid-decode, finished {finish:?}"
            );
            anyhow::ensure!(
                n_tokens < budgets[0],
                "cancellation had no effect: all {n_tokens} tokens were produced"
            );
        } else if greedy_run {
            anyhow::ensure!(
                finish == FinishReason::Length && n_tokens == budgets[i],
                "short stream {i}: expected {} tokens + Length, got {n_tokens} + {finish:?}",
                budgets[i]
            );
        }
    }
    let m = router.metrics.summary();
    anyhow::ensure!(
        m.arena_slots_in_use == 0,
        "KV arena still holds {} slots after all streams finished",
        m.arena_slots_in_use
    );
    anyhow::ensure!(
        m.cancelled == 1 && m.errored == 0 && m.completed == n_requests - 1,
        "outcome split wrong: completed {} cancelled {} errored {} (expected {}/1/0)",
        m.completed,
        m.cancelled,
        m.errored,
        n_requests - 1
    );
    println!("stream smoke OK — cancellation released its slot, shorts met their budgets");
    Ok(())
}

/// Prefix-cache smoke (`--stream --prefix-cache`): two requests sharing
/// a system prompt are decoded cold (cache-off router) and then twice
/// through the warm router. Hard-fails on any token mismatch vs the
/// cold run, on the cache never hitting, on undrained sessions, or on
/// page residency growing across identical rounds (a page leak).
fn prefix_smoke(
    warm: &Router,
    cold: &Router,
    tok: &Tokenizer,
    params: &SamplingParams,
) -> Result<()> {
    let sys = tok.encode("17+25=42 9+3=12 ");
    let mk = |user: &str| {
        let mut p = sys.clone();
        p.extend(tok.encode(user));
        p
    };
    let prompts = [mk("11+7="), mk("8+6=")];
    println!(
        "prefix smoke: 2 requests sharing a {}-token system prompt, cold vs warm x2",
        sys.len()
    );
    let cold_tokens: Vec<Vec<u32>> = prompts
        .iter()
        .map(|p| cold.submit_with(p.clone(), params.clone(), 0).collect().map(|r| r.tokens))
        .collect::<Result<_>>()?;
    let mut pages_after_round = Vec::new();
    for round in 0..2 {
        for (i, p) in prompts.iter().enumerate() {
            let resp = warm.submit_with(p.clone(), params.clone(), 0).collect()?;
            anyhow::ensure!(
                resp.tokens == cold_tokens[i],
                "prefix smoke: round {round} request {i} diverged from the cold run \
                 ({:?} vs {:?})",
                resp.tokens,
                cold_tokens[i]
            );
        }
        let m = warm.metrics.summary();
        anyhow::ensure!(
            m.arena_slots_in_use == 0,
            "prefix smoke: sessions not drained after round {round}"
        );
        pages_after_round.push(m.arena_pages_in_use);
    }
    let m = warm.metrics.summary();
    anyhow::ensure!(
        m.prefix_hits >= 2,
        "prefix smoke: repeated shared-prefix prompts never hit the cache ({} hits)",
        m.prefix_hits
    );
    anyhow::ensure!(
        pages_after_round[1] <= pages_after_round[0],
        "prefix smoke: page residency grew across identical rounds ({} -> {}) — leaked pages",
        pages_after_round[0],
        pages_after_round[1]
    );
    println!(
        "prefix smoke OK — {} hits, {} prompt tokens borrowed, {} pages resident at drain",
        m.prefix_hits, m.prefix_hit_tokens, m.arena_pages_in_use
    );
    Ok(())
}

/// Chunked-prefill smoke (`--stream --prefill-chunk N`): one long
/// prompt and several short ones submitted together through the
/// chunked router and through a chunk-1 reference router over the same
/// engine. Hard-fails on any token or finish-reason divergence, on a
/// missing prefill-rate measurement, or on leaked slots — the CI gate
/// for the chunked prefill path.
fn chunked_smoke(
    chunked: &Router,
    reference: &Router,
    tok: &Tokenizer,
    params: &SamplingParams,
    max_new: usize,
    capacity: usize,
) -> Result<()> {
    // A long prompt (several chunks worth) plus shorts, all within the
    // model's decode capacity.
    let stem = "17+25=42 9+3=12 8+6=14 11+7=18 ";
    let mut long = tok.encode(&stem.repeat(4));
    long.truncate(capacity.saturating_sub(max_new + 1).min(48).max(4));
    let shorts = tasks::gen_arith(0xBEEF, 4, 2);
    let mut prompts = vec![long];
    prompts.extend(shorts.iter().map(|t| tok.encode(&t.prompt)));
    println!(
        "chunked smoke: 1 long ({} tokens) + {} short prompts, chunked vs chunk-1 reference",
        prompts[0].len(),
        prompts.len() - 1
    );
    let run = |router: &Router| -> Result<Vec<Vec<u32>>> {
        let streams: Vec<_> = prompts
            .iter()
            .map(|p| router.submit_with(p.clone(), params.clone(), 0))
            .collect();
        streams.into_iter().map(|s| s.collect().map(|r| r.tokens)).collect()
    };
    let got = run(chunked)?;
    let want = run(reference)?;
    for (i, (g, w)) in got.iter().zip(&want).enumerate() {
        anyhow::ensure!(
            g == w,
            "chunked smoke: request {i} diverged from the chunk-1 reference ({g:?} vs {w:?})"
        );
    }
    let m = chunked.metrics.summary();
    anyhow::ensure!(
        m.prefill_tokens_per_sec > 0.0,
        "chunked smoke: no prefill rate was measured"
    );
    anyhow::ensure!(
        m.arena_slots_in_use == 0,
        "chunked smoke: {} KV arena slots leaked",
        m.arena_slots_in_use
    );
    println!(
        "chunked smoke OK — token-identical to chunk 1, prefill {:.0} tok/s, no leaked slots",
        m.prefill_tokens_per_sec
    );
    Ok(())
}

fn print_summary(router: &Router) {
    let s = router.metrics.summary();
    println!("requests completed : {}", s.completed);
    println!("cancelled / errored: {} / {}", s.cancelled, s.errored);
    println!("tokens generated   : {}", s.tokens);
    println!("p50 TTFT           : {:.2} ms", s.p50_first_us as f64 / 1e3);
    println!("p95 TTFT           : {:.2} ms", s.p95_first_us as f64 / 1e3);
    println!("p50 inter-token    : {:.2} ms", s.p50_itl_us as f64 / 1e3);
    println!("p95 inter-token    : {:.2} ms", s.p95_itl_us as f64 / 1e3);
    println!("p50 queue delay    : {:.2} ms", s.p50_queue_us as f64 / 1e3);
    println!(
        "p50/p95 prefill    : {:.2} / {:.2} ms",
        s.p50_prefill_us as f64 / 1e3,
        s.p95_prefill_us as f64 / 1e3
    );
    println!(
        "p50/p95 first dec. : {:.2} / {:.2} ms",
        s.p50_first_decode_us as f64 / 1e3,
        s.p95_first_decode_us as f64 / 1e3
    );
    println!("prefill rate       : {:.1} tok/s", s.prefill_tokens_per_sec);
    println!(
        "decode sweeps      : {} (mean batch {:.2}, max {})",
        s.decode_sweeps, s.mean_decode_batch, s.max_decode_batch
    );
    println!(
        "kv arena           : {} slots in use (high water {}), {:.2} MiB resident, {} fork copies",
        s.arena_slots_in_use,
        s.arena_high_water,
        s.arena_bytes_resident as f64 / (1 << 20) as f64,
        s.arena_fork_copies
    );
    println!(
        "kv pages           : {} in use ({} shared), {} COW copies",
        s.arena_pages_in_use, s.arena_pages_shared, s.arena_cow_copies
    );
    println!(
        "prefix cache       : {} lookups, {} hits, {} prompt tokens borrowed",
        s.prefix_lookups, s.prefix_hits, s.prefix_hit_tokens
    );
    println!(
        "kv bytes/session   : {} (real packed slot bytes)",
        s.arena_slot_bytes
    );
    println!("decode             : {:.1} µs/token", s.us_per_token);
    println!("throughput         : {:.1} tok/s", s.tokens_per_sec);
    println!("simd tier          : {}", s.simd_tier);
    println!("summary json       : {}", s.to_json());
}

/// `serve --listen <addr>`: serve the router over HTTP/SSE until a
/// drain (`POST /admin/drain`) completes, then print the summary and
/// hard-check for leaks — a drained server must hold zero KV arena
/// slots, and (without a prefix cache, which retains pages by design)
/// zero KV pages.
fn run_listen(
    args: &Args,
    addr: &str,
    router: Router,
    tok: Tokenizer,
    model: &Model,
    prefix_cache: bool,
    params: SamplingParams,
) -> Result<()> {
    // --deadline-budget-us N: admission control threshold; absent = off.
    let deadline_budget_us = match args.get("deadline-budget-us") {
        Some(_) => {
            let us = args.get_usize("deadline-budget-us", 0).map_err(anyhow::Error::msg)?;
            Some(us as u64)
        }
        None => None,
    };
    let cfg = ServerConfig {
        max_conns: args.get_usize("max-conns", 64).map_err(anyhow::Error::msg)?,
        deadline_budget_us,
        keepalive_ms: args.get_usize("keepalive-ms", 5_000).map_err(anyhow::Error::msg)? as u64,
        io_timeout_ms: args.get_usize("io-timeout-ms", 30_000).map_err(anyhow::Error::msg)? as u64,
        tenant_priority: parse_tenants(args.get_or("tenant-priority", ""))?,
        default_params: params,
        capacity: model.decode_capacity(),
        vocab_size: model.cfg.vocab_size as u32,
    };
    let router = Arc::new(router);
    let server = Server::start(addr, router.clone(), Arc::new(tok), cfg)?;
    println!(
        "listening on {} (POST /v1/generate streams SSE; POST /admin/drain to stop)",
        server.local_addr()
    );
    // --addr-file: publish the bound address (with the OS-assigned port
    // when listening on :0) for wire clients like `bpdq loadgen`.
    if let Some(path) = args.get("addr-file") {
        std::fs::write(path, server.local_addr().to_string())
            .with_context(|| format!("writing --addr-file {path}"))?;
    }
    server.join()?;
    println!("\n--- drained: final summary ---");
    print_summary(&router);
    let m = router.metrics.summary();
    anyhow::ensure!(
        m.arena_slots_in_use == 0,
        "drain leaked {} KV arena slots",
        m.arena_slots_in_use
    );
    if !prefix_cache {
        let pages = m.arena_pages_in_use;
        anyhow::ensure!(pages == 0, "drain leaked {pages} KV pages");
    }
    router.shutdown();
    println!("drain complete — no leaked slots or pages");
    Ok(())
}

/// Parse `--tenant-priority "gold=9,free=0"` into the server's map.
fn parse_tenants(spec: &str) -> Result<Vec<(String, u8)>> {
    let mut out = Vec::new();
    for part in spec.split(',').filter(|p| !p.trim().is_empty()) {
        let (name, prio) = part
            .split_once('=')
            .with_context(|| format!("--tenant-priority: `{part}` is not name=priority"))?;
        let p: u8 = prio
            .trim()
            .parse()
            .with_context(|| format!("--tenant-priority: bad priority in `{part}`"))?;
        out.push((name.trim().to_string(), p));
    }
    Ok(out)
}
