//! `bpdq serve` — quantize a checkpoint, start the router/worker pool on
//! the chosen engine, push a synthetic request trace through it, and
//! report serving metrics. The W2-G256-on-one-GPU headline (§4.2) maps
//! to: quantize at W2-G256, report the exact packed size, and serve.

use anyhow::Result;
use bpdq::cli::Args;
use bpdq::data::tasks;
use bpdq::model::pipeline::quantize_model;
use bpdq::quant::{BpdqConfig, QuantMethod};
use bpdq::serving::{EngineKind, LutModel, Router, RouterConfig, Strategy};
use std::collections::HashMap;
use std::sync::Arc;
use std::time::Duration;

use super::quantize::{calib_seqs, load_context, parse_method};

pub fn run(args: &Args) -> Result<()> {
    let model_path = args.get_or("model", "artifacts/tiny_small.tlm");
    let engine_name = args.get_or("engine", "lut");
    let n_requests = args.get_usize("requests", 24).map_err(anyhow::Error::msg)?;
    let n_workers = args.get_usize("workers", 2).map_err(anyhow::Error::msg)?;
    let max_new = args.get_usize("max-new", 8).map_err(anyhow::Error::msg)?;

    let (model, gen, tok) = load_context(model_path)?;
    let model = Arc::new(model);

    // Quantize (default BPDQ W2-G256 — the paper's extreme deployment
    // point) unless serving fp16 natively.
    let kind: EngineKind = match engine_name {
        "native-fp16" => EngineKind::Native(model.clone()),
        "pjrt" => {
            let artifact = std::path::PathBuf::from(
                args.get_or("artifact", "artifacts/decode_step.hlo.txt"),
            );
            anyhow::ensure!(artifact.exists(), "missing {}", artifact.display());
            let cache_len = args.get_usize("cache-len", 256).map_err(anyhow::Error::msg)?;
            EngineKind::Pjrt { model: model.clone(), artifact, cache_len }
        }
        "native" | "lut" => {
            let method = if args.has("method") {
                parse_method(args)?
            } else {
                QuantMethod::Bpdq(BpdqConfig { k: 2, group_size: 256, ..Default::default() })
            };
            let calib = calib_seqs(&gen, &tok, 48, model.cfg.max_seq);
            println!("quantizing with {} …", method.name());
            let qm = quantize_model(&model, &calib, &method)?;
            println!(
                "quantized: BPW {:.2}, packed size {:.2} MiB (fp16 {:.2} MiB)",
                qm.bits_per_weight(),
                qm.size_bytes() as f64 / (1 << 20) as f64,
                model.fp16_bytes() as f64 / (1 << 20) as f64
            );
            let qmodel = Arc::new(qm.model.clone());
            if engine_name == "lut" {
                let packed: HashMap<_, _> = qm
                    .packed
                    .iter()
                    .map(|(k, v)| {
                        (
                            k.clone(),
                            v.as_bit_planes()
                                .expect("BPDQ/BCQ packing required for the LUT engine")
                                .clone(),
                        )
                    })
                    .collect();
                EngineKind::Lut(LutModel::new(qmodel, packed)?)
            } else {
                EngineKind::Native(qmodel)
            }
        }
        other => anyhow::bail!("unknown engine `{other}` (native|native-fp16|lut|pjrt)"),
    };

    println!("starting router: {n_workers} workers, engine={engine_name}");
    let router = Router::start(
        RouterConfig {
            n_workers,
            max_batch: 8,
            batch_window: Duration::from_millis(2),
            strategy: Strategy::LeastLoaded,
        },
        |_| kind.clone(),
    )?;

    // Request trace: few-shot arithmetic prompts (the interactive-decode
    // workload of Table 3).
    let trace = tasks::gen_arith(0xC0FFEE, n_requests, 2);
    let rxs: Vec<_> = trace
        .iter()
        .map(|t| router.submit(tok.encode(&t.prompt), max_new))
        .collect();
    let mut correct = 0usize;
    for ((_, rx), t) in rxs.into_iter().zip(&trace) {
        let resp = rx.recv()?;
        let text = tok.decode(&resp.tokens);
        if text.starts_with(t.answer.as_str()) {
            correct += 1;
        }
    }
    let s = router.metrics.summary();
    println!("\n--- serving report ---");
    println!("requests completed : {}", s.completed);
    println!("exact-match        : {:.1}%", 100.0 * correct as f64 / trace.len() as f64);
    println!("tokens generated   : {}", s.tokens);
    println!("p50 first-token    : {:.2} ms", s.p50_first_us as f64 / 1e3);
    println!("p95 first-token    : {:.2} ms", s.p95_first_us as f64 / 1e3);
    println!("p50 queue delay    : {:.2} ms", s.p50_queue_us as f64 / 1e3);
    println!("mean batch size    : {:.2}", s.mean_batch);
    println!(
        "decode sweeps      : {} (mean batch {:.2}, max {})",
        s.decode_sweeps, s.mean_decode_batch, s.max_decode_batch
    );
    println!(
        "kv arena           : {} slots in use (high water {}), {:.2} MiB resident, {} fork copies",
        s.arena_slots_in_use,
        s.arena_high_water,
        s.arena_bytes_resident as f64 / (1 << 20) as f64,
        s.arena_fork_copies
    );
    println!("decode             : {:.1} µs/token", s.us_per_token);
    println!("throughput         : {:.1} tok/s", s.tokens_per_sec);
    println!("summary json       : {}", s.to_json());
    router.shutdown();
    Ok(())
}
