//! `bpdq selfcheck` — end-to-end artifact verification:
//!
//! 1. vocab artifact matches the rust tokenizer;
//! 2. PJRT loads + runs both kernel artifacts and their outputs agree
//!    with the native rust LUT engine on the same packed weights
//!    (three-implementation agreement: Pallas ref ↔ AOT HLO ↔ rust LUT);
//! 3. the decode-step artifact (if present) agrees with the native
//!    forward of the trained checkpoint.

use anyhow::{Context, Result};
use bpdq::cli::Args;
use bpdq::data::Tokenizer;
use bpdq::io::tlm::TlmFile;
use bpdq::model::Model;
use bpdq::quant::packing::{BitPlanePacked, PackedPlane};
use bpdq::rng::Rng;
use bpdq::runtime::{self, Runtime};
use bpdq::tensor::Matrix;
use std::path::Path;

pub fn run(args: &Args) -> Result<()> {
    let dir = Path::new(args.get_or("artifacts", "artifacts"));
    let mut failures = 0;

    // 1. vocab sync
    let tok = Tokenizer::new();
    match tok.verify_artifact(&dir.join("vocab.txt")) {
        Ok(()) => println!("[ok] vocab.txt matches rust tokenizer ({} chars)", tok.vocab_size()),
        Err(e) => {
            println!("[FAIL] vocab: {e:#}");
            failures += 1;
        }
    }

    // 2. kernel artifacts vs native LUT (requires the PJRT plugin; the
    // offline xla stub reports it unavailable, which is a skip, not a
    // failure — the native LUT path is still fully checked by `cargo
    // test`).
    let mut rt = match Runtime::cpu() {
        Ok(rt) => rt,
        Err(e) => {
            println!("[skip] PJRT unavailable ({e:#}) — skipping kernel/decode-step checks");
            anyhow::ensure!(failures == 0, "{failures} selfcheck failure(s)");
            println!("\nselfcheck OK (PJRT checks skipped)");
            return Ok(());
        }
    };
    println!("[ok] PJRT client: {}", rt.platform());
    let (k, d_out, d_in, g) = (2usize, 128usize, 128usize, 64usize);
    let packed = random_packed(42, d_out, d_in, g, k);
    let mut rng = Rng::new(43);
    let x: Vec<f32> = (0..d_in).map(|_| rng.normal() as f32).collect();

    // native
    let mut y_native = vec![0.0f32; d_out];
    bpdq::lut::lut_gemv(&packed, &x, &mut y_native, &mut bpdq::lut::LutScratch::default());

    for name in ["bpdq_gemv", "dequant_gemv"] {
        let path = dir.join(format!("{name}.hlo.txt"));
        if !path.exists() {
            println!("[FAIL] missing artifact {}", path.display());
            failures += 1;
            continue;
        }
        let y = run_kernel_artifact(&mut rt, &path, &packed, &x)
            .with_context(|| name.to_string())?;
        let max_err = y
            .iter()
            .zip(&y_native)
            .map(|(a, b)| (a - b).abs() / (1.0 + b.abs()))
            .fold(0.0f32, f32::max);
        if max_err < 1e-3 {
            println!("[ok] {name}.hlo.txt matches native LUT (max rel err {max_err:.2e})");
        } else {
            println!("[FAIL] {name}.hlo.txt deviates (max rel err {max_err:.2e})");
            failures += 1;
        }
    }

    // 3. decode step artifact vs native forward
    let ckpt = dir.join("tiny_small.tlm");
    let step_artifact = dir.join("decode_step.hlo.txt");
    if ckpt.exists() && step_artifact.exists() {
        let model = Model::from_tlm(&TlmFile::load(&ckpt)?)?;
        let meta = std::fs::read_to_string(dir.join("decode_step.meta")).unwrap_or_default();
        let meta_field = |key: &str| -> Option<usize> {
            meta.lines()
                .find(|l| l.starts_with(key))
                .and_then(|l| l.split_whitespace().nth(1))
                .and_then(|v| v.parse().ok())
        };
        let cache_len = meta_field("cache_len").unwrap_or(256);
        // GQA-aware artifacts record their kv_dim; legacy ones thread a
        // d_model-wide cache.
        let kv_dim = meta_field("kv_dim").unwrap_or(model.cfg.d_model);
        let toks = [5u32, 9, 3, 14, 7];
        let native = model.forward_full(&toks);
        let exe = rt.load(&step_artifact)?;
        let nl = model.cfg.n_layers;
        let zeros = vec![0.0f32; nl * cache_len * kv_dim];
        let dims = [nl as i64, cache_len as i64, kv_dim as i64];
        let mut klit = runtime::literal_f32(&zeros, &dims)?;
        let mut vlit = runtime::literal_f32(&zeros, &dims)?;
        let mut max_err = 0.0f32;
        for (t, &tok_id) in toks.iter().enumerate() {
            let out = exe.run(&[
                runtime::literal_i32(tok_id as i32),
                runtime::literal_i32(t as i32),
                klit,
                vlit,
            ])?;
            let mut it = out.into_iter();
            let logits = runtime::to_f32_vec(&it.next().context("logits")?)?;
            klit = it.next().context("k")?;
            vlit = it.next().context("v")?;
            for v in 0..model.cfg.vocab_size {
                let a = native.get(t, v);
                max_err = max_err.max((logits[v] - a).abs() / (1.0 + a.abs()));
            }
        }
        if max_err < 5e-3 {
            println!("[ok] decode_step.hlo.txt matches native forward (max rel err {max_err:.2e})");
        } else {
            println!("[FAIL] decode_step deviates from native forward ({max_err:.2e})");
            failures += 1;
        }
    } else {
        println!("[skip] decode_step check ({} or {} missing)", ckpt.display(), step_artifact.display());
    }

    anyhow::ensure!(failures == 0, "{failures} selfcheck failure(s)");
    println!("\nselfcheck OK");
    Ok(())
}

/// Execute one kernel artifact on packed weights (converting to the
/// python byte layout: (k, d_out, d_in/8) u8 + (k+1, d_out, ng) f32).
fn run_kernel_artifact(
    rt: &mut Runtime,
    path: &Path,
    packed: &BitPlanePacked,
    x: &[f32],
) -> Result<Vec<f32>> {
    let (k, d_out, d_in) = (packed.k(), packed.d_out, packed.d_in);
    let ng = packed.n_groups();
    let mut bytes = Vec::with_capacity(k * d_out * (d_in / 8));
    for plane in &packed.planes {
        for r in 0..d_out {
            let words = plane.row_words(r);
            for c in 0..d_in / 8 {
                bytes.push(((words[c / 4] >> (8 * (c % 4))) & 0xFF) as u8);
            }
        }
    }
    let mut coeffs = Vec::with_capacity((k + 1) * d_out * ng);
    for c in &packed.coeffs {
        coeffs.extend_from_slice(c.data());
    }
    let exe = rt.load(path)?;
    let out = exe.run(&[
        runtime::literal_f32(x, &[d_in as i64])?,
        runtime::literal_u8(&bytes, &[k, d_out, d_in / 8])?,
        runtime::literal_f32(&coeffs, &[(k + 1) as i64, d_out as i64, ng as i64])?,
    ])?;
    runtime::to_f32_vec(&out[0])
}

fn random_packed(seed: u64, d_out: usize, d_in: usize, g: usize, k: usize) -> BitPlanePacked {
    let mut rng = Rng::new(seed);
    let planes = (0..k)
        .map(|_| {
            let dense = Matrix::from_vec(
                d_out,
                d_in,
                (0..d_out * d_in).map(|_| if rng.coin(0.5) { 1.0 } else { 0.0 }).collect(),
            );
            PackedPlane::pack(&dense)
        })
        .collect();
    let ng = d_in.div_ceil(g);
    let coeffs = (0..=k)
        .map(|_| Matrix::from_vec(d_out, ng, (0..d_out * ng).map(|_| rng.normal() as f32).collect()))
        .collect();
    BitPlanePacked { d_out, d_in, group_size: g, planes, coeffs, coeff_bits: 16 }
}
