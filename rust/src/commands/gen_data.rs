//! `bpdq gen-data` — write the synthetic corpus + vocab artifacts the
//! python trainer consumes. Rust is the single source of truth for data.

use anyhow::{Context, Result};
use bpdq::cli::Args;
use bpdq::data::corpus::{CorpusConfig, CorpusGen, Split};
use bpdq::data::tokenizer::VOCAB;
use std::fs;
use std::path::Path;

pub fn run(args: &Args) -> Result<()> {
    let out = args.get_or("out", "artifacts");
    let train_docs = args.get_usize("train-docs", 60_000).map_err(anyhow::Error::msg)?;
    let eval_docs = args.get_usize("eval-docs", 2_000).map_err(anyhow::Error::msg)?;
    let calib_docs = args.get_usize("calib-docs", 1_024).map_err(anyhow::Error::msg)?;
    let seed = args
        .get_usize("seed", CorpusConfig::default().seed as usize)
        .map_err(anyhow::Error::msg)? as u64;

    let dir = Path::new(out);
    fs::create_dir_all(dir).with_context(|| format!("mkdir {out}"))?;

    // vocab.txt: one char per line, newline escaped.
    let vocab_lines: String = VOCAB
        .chars()
        .map(|c| if c == '\n' { "\\n\n".to_string() } else { format!("{c}\n") })
        .collect();
    fs::write(dir.join("vocab.txt"), vocab_lines)?;

    let gen = CorpusGen::new(CorpusConfig { seed, ..Default::default() });
    for (split, n, name) in [
        (Split::Train, train_docs, "corpus_train.txt"),
        (Split::Eval, eval_docs, "corpus_eval.txt"),
        (Split::Calib, calib_docs, "corpus_calib.txt"),
    ] {
        let text = gen.generate(split, n);
        fs::write(dir.join(name), &text)?;
        println!("wrote {}/{name}: {} docs, {} chars", out, n, text.len());
    }
    println!("gen-data done (seed={seed:#x})");
    Ok(())
}
