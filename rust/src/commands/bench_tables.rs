//! Table/figure regeneration subcommands — thin wrappers over
//! [`bpdq::report::harness`] (the cargo benches call the same functions,
//! so CLI output and bench output are identical by construction).

use anyhow::Result;
use bpdq::cli::Args;
use bpdq::report::harness::{self, HarnessCfg};

fn cfg(args: &Args) -> HarnessCfg {
    let default_model = match args.get_or("model", "small") {
        "large" => "artifacts/tiny_large.tlm",
        path if path.ends_with(".tlm") => path,
        _ => "artifacts/tiny_small.tlm",
    };
    HarnessCfg::new(default_model, args.has("quick"))
}

pub fn table1(args: &Args) -> Result<()> {
    harness::table1(&cfg(args)).map(|_| ())
}

pub fn table2(args: &Args) -> Result<()> {
    harness::table2(&cfg(args)).map(|_| ())
}

pub fn table3(args: &Args) -> Result<()> {
    harness::table3(&cfg(args))
}

pub fn fig1b(args: &Args) -> Result<()> {
    harness::fig1b(&cfg(args)).map(|_| ())
}

pub fn fig3(args: &Args) -> Result<()> {
    harness::fig3(&cfg(args))
}
