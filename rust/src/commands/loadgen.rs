//! `bpdq loadgen` — wire-level load generator for `serve --listen`.
//!
//! Replays a Zipf-distributed prompt workload (a hot head of shared
//! prompts over a common stem — the traffic shape prefix caching is
//! built for) against a live server over real sockets, measuring
//! client-side TTFT/ITL from SSE (or raw-protocol) frame arrival
//! times. Emits a `BENCH_serve_load.json` artifact (goodput, latency
//! percentiles, rejection rate, cache hit rate) for the CI perf gate,
//! and optionally:
//!
//! * `--drain` — post `/admin/drain` when done, so a CI leg can `wait`
//!   on the serve process and check its leak gates;
//! * `--verify-inprocess` — rebuild the *identical* engine from the
//!   same flags ([`super::serve::build_setup`]) and require every
//!   accepted stream's wire tokens to match in-process decoding;
//! * `--require-all` / `--expect-rejections` — hard gates for the
//!   parity and overload CI legs.

use anyhow::{Context, Result};
use bpdq::benchkit::JsonReport;
use bpdq::cli::Args;
use bpdq::data::Tokenizer;
use bpdq::io::json::{JsonValue, JsonWriter};
use bpdq::rng::{Rng, Zipf};
use bpdq::serving::net::server::RAW_MAGIC;
use bpdq::serving::{Router, RouterConfig, Strategy};
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use super::serve::{build_setup, sampling_params, ServeSetup};

/// One precomputed request: prompt token ids + per-request overrides.
struct Spec {
    tokens: Vec<u32>,
    max_new: usize,
    seed: u64,
}

/// What one wire request amounted to.
#[derive(Clone)]
enum Outcome {
    /// Stream completed; latencies are client-observed arrival times.
    Ok { tokens: Vec<u32>, ttft_us: u64, itl_us: Vec<u64> },
    /// The server said no (429 overload, 503 drain/pool-full, 4xx).
    Rejected { status: u16 },
    /// Transport-level failure — always a bug somewhere; always fatal.
    Failed(String),
}

pub fn run(args: &Args) -> Result<()> {
    let addr = resolve_addr(args)?;
    let n_requests = args.get_usize("requests", 64).map_err(anyhow::Error::msg)?.max(1);
    let concurrency = args.get_usize("concurrency", 8).map_err(anyhow::Error::msg)?.max(1);
    let pool = args.get_usize("pool", 16).map_err(anyhow::Error::msg)?.max(1);
    let zipf_s = args.get_f64("zipf-s", 1.1).map_err(anyhow::Error::msg)?;
    let max_new = args.get_usize("max-new", 8).map_err(anyhow::Error::msg)?;
    let seed = args.get_usize("seed", 0).map_err(anyhow::Error::msg)? as u64;
    let raw = args.has("raw");
    let out_path = args.get_or("out", "BENCH_serve_load.json").to_string();
    let name = args.get_or("name", "serve_load").to_string();
    // --prompt-len-dist bimodal: every 4th request carries a long
    // (~LONG_PROMPT_LEN-token) prompt — the mixed prefill/decode load
    // chunked prefill exists for. Short-request TTFT is reported
    // separately so the gate sees whether long prefills stall shorts.
    let dist = args.get_or("prompt-len-dist", "uniform");
    let bimodal = match dist {
        "uniform" => false,
        "bimodal" => true,
        other => anyhow::bail!("--prompt-len-dist must be uniform|bimodal, got `{other}`"),
    };

    wait_ready(&addr, Duration::from_secs(15))?;
    let specs = Arc::new(build_specs(n_requests, pool, zipf_s, max_new, seed, bimodal));
    println!(
        "loadgen: {n_requests} requests over {concurrency} conns to {addr} ({} wire, \
         zipf s={zipf_s} over {pool} prompts, {dist} lengths)",
        if raw { "raw" } else { "http/sse" }
    );

    let t0 = Instant::now();
    let outcomes = fire(&addr, &specs, concurrency, raw)?;
    let wall = t0.elapsed();

    // Scrape server-side counters before draining the server away.
    let server_metrics = fetch_metrics(&addr).ok();
    if args.has("drain") {
        post_drain(&addr)?;
        println!("drain requested — server is finishing in-flight streams");
    }
    if args.has("verify-inprocess") {
        verify_inprocess(args, &specs, &outcomes)?;
    }

    let agg = aggregate(&outcomes);
    anyhow::ensure!(
        agg.failures.is_empty(),
        "{} transport failures, first: {}",
        agg.failures.len(),
        agg.failures[0]
    );
    let goodput = agg.tokens as f64 / wall.as_secs_f64().max(1e-9);
    let rejection_rate = agg.rejected as f64 / n_requests as f64;
    let (hits, lookups, srv) = summarize_server(server_metrics.as_ref());
    let cache_hit_rate = if lookups > 0 { hits as f64 / lookups as f64 } else { 0.0 };

    println!("\n--- loadgen report ---");
    println!("accepted / rejected: {} / {} (of {n_requests})", agg.accepted, agg.rejected);
    if !agg.rejected_by.is_empty() {
        let parts: Vec<String> =
            agg.rejected_by.iter().map(|(s, n)| format!("{n} x {s}")).collect();
        println!("rejections         : {}", parts.join(", "));
    }
    println!(
        "goodput            : {goodput:.1} tok/s ({} tokens in {:.2} s)",
        agg.tokens,
        wall.as_secs_f64()
    );
    println!(
        "TTFT p50 / p95     : {:.2} / {:.2} ms",
        pct(&agg.ttft_us, 0.5) as f64 / 1e3,
        pct(&agg.ttft_us, 0.95) as f64 / 1e3
    );
    println!(
        "ITL  p50 / p95     : {:.2} / {:.2} ms",
        pct(&agg.itl_us, 0.5) as f64 / 1e3,
        pct(&agg.itl_us, 0.95) as f64 / 1e3
    );
    // Short-request TTFT, classified post-hoc by prompt length — under
    // a bimodal mix this is the stall-free-scheduling signal.
    let mut short_ttft_us: Vec<u64> = specs
        .iter()
        .zip(&outcomes)
        .filter_map(|(sp, o)| match o {
            Outcome::Ok { ttft_us, .. } if sp.tokens.len() < LONG_PROMPT_LEN / 2 => {
                Some(*ttft_us)
            }
            _ => None,
        })
        .collect();
    short_ttft_us.sort_unstable();
    if bimodal {
        println!(
            "short TTFT p50/p95 : {:.2} / {:.2} ms ({} short streams)",
            pct(&short_ttft_us, 0.5) as f64 / 1e3,
            pct(&short_ttft_us, 0.95) as f64 / 1e3,
            short_ttft_us.len()
        );
    }
    println!("prefix cache       : {hits}/{lookups} hits ({:.0}%)", 100.0 * cache_hit_rate);
    println!("server counters    : {srv}");

    let kv_bits = args.get_usize("kv-bits", 0).map_err(anyhow::Error::msg)?;
    let mut rep = JsonReport::new("serve_load", &out_path);
    rep.row(|w| {
        w.begin_object()
            .key("name")
            .string(&name)
            .key("requests")
            .int(n_requests as i64)
            .key("concurrency")
            .int(concurrency as i64)
            .key("accepted")
            .int(agg.accepted as i64)
            .key("rejected")
            .int(agg.rejected as i64)
            .key("rejection_rate")
            .number(rejection_rate)
            .key("goodput_tok_s")
            .number(goodput)
            .key("ttft_p50_us")
            .int(pct(&agg.ttft_us, 0.5) as i64)
            .key("ttft_p95_us")
            .int(pct(&agg.ttft_us, 0.95) as i64)
            .key("itl_p50_us")
            .int(pct(&agg.itl_us, 0.5) as i64)
            .key("itl_p95_us")
            .int(pct(&agg.itl_us, 0.95) as i64)
            .key("short_ttft_p50_us")
            .int(pct(&short_ttft_us, 0.5) as i64)
            .key("short_ttft_p95_us")
            .int(pct(&short_ttft_us, 0.95) as i64)
            .key("cache_hit_rate")
            .number(cache_hit_rate)
            .key("kv_bits")
            .int(kv_bits as i64)
            .end_object();
    });
    rep.finish();

    if args.has("require-all") {
        anyhow::ensure!(
            agg.accepted == n_requests,
            "--require-all: only {}/{n_requests} streams completed ({} rejected)",
            agg.accepted,
            agg.rejected
        );
    }
    if args.has("expect-rejections") {
        anyhow::ensure!(
            agg.rejected > 0 && agg.accepted > 0,
            "--expect-rejections: wanted both rejections and completions, got {} / {}",
            agg.accepted,
            agg.rejected
        );
    }
    Ok(())
}

/// `--addr host:port`, or poll `--addr-file` until a `serve --listen`
/// process publishes its bound address there.
fn resolve_addr(args: &Args) -> Result<String> {
    if let Some(a) = args.get("addr") {
        return Ok(a.to_string());
    }
    let path = args.get("addr-file").context("loadgen needs --addr or --addr-file")?;
    let deadline = Instant::now() + Duration::from_secs(15);
    loop {
        if let Ok(text) = std::fs::read_to_string(path) {
            let text = text.trim();
            if !text.is_empty() {
                return Ok(text.to_string());
            }
        }
        anyhow::ensure!(
            Instant::now() < deadline,
            "timed out waiting for --addr-file {path} to appear"
        );
        std::thread::sleep(Duration::from_millis(50));
    }
}

/// Long-prompt length for `--prompt-len-dist bimodal` — several prefill
/// chunks worth, and the short/long classification threshold (shorts
/// are anything under half of this).
const LONG_PROMPT_LEN: usize = 96;

/// The request mix: every prompt shares a 24-token stem (prefix-cache
/// bait), prompts are reused Zipf-fashion (rank 0 hottest), and each
/// request carries its own seed so the server's per-request sampling
/// state is exercised. With `bimodal`, every 4th request swaps in a
/// [`LONG_PROMPT_LEN`]-token prompt over the same stem.
fn build_specs(
    n: usize,
    pool: usize,
    zipf_s: f64,
    max_new: usize,
    seed: u64,
    bimodal: bool,
) -> Vec<Spec> {
    let vocab = Tokenizer::new().vocab_size();
    let stem: Vec<u32> = (0..24usize).map(|t| ((t * 5 + 3) % vocab) as u32).collect();
    let prompts: Vec<Vec<u32>> = (0..pool)
        .map(|i| {
            let mut p = stem.clone();
            p.extend((0..4 + i % 3).map(|j| ((i * 7 + j * 11 + 5) % vocab) as u32));
            p
        })
        .collect();
    let longs: Vec<Vec<u32>> = (0..pool.min(4))
        .map(|i| {
            let mut p = stem.clone();
            p.extend(
                (0..LONG_PROMPT_LEN - stem.len())
                    .map(|j| ((i * 13 + j * 7 + 1) % vocab) as u32),
            );
            p
        })
        .collect();
    let zipf = Zipf::new(pool, zipf_s);
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| {
            let rank = zipf.sample(&mut rng);
            Spec {
                tokens: if bimodal && i % 4 == 0 {
                    longs[rank % longs.len()].clone()
                } else {
                    prompts[rank].clone()
                },
                max_new,
                seed: seed.wrapping_add(i as u64),
            }
        })
        .collect()
}

/// Poll `GET /healthz` until the server answers any HTTP status —
/// except `degraded`, which means a worker is already dead and every
/// generate would hang or error; fail fast instead.
fn wait_ready(addr: &str, timeout: Duration) -> Result<()> {
    let deadline = Instant::now() + timeout;
    loop {
        if let Ok(mut s) = connect(addr) {
            let probe = b"GET /healthz HTTP/1.1\r\nHost: loadgen\r\n\r\n";
            let mut text = String::new();
            if s.write_all(probe).is_ok()
                && s.read_to_string(&mut text).is_ok()
                && text.starts_with("HTTP/1.1")
            {
                anyhow::ensure!(
                    !text.contains(r#""status":"degraded""#),
                    "server at {addr} is degraded: {text}"
                );
                return Ok(());
            }
        }
        anyhow::ensure!(
            Instant::now() < deadline,
            "no server answered /healthz at {addr} within {timeout:?}"
        );
        std::thread::sleep(Duration::from_millis(50));
    }
}

/// Claim-by-atomic-counter work distribution over `concurrency`
/// threads; every request records exactly one outcome slot.
fn fire(
    addr: &str,
    specs: &Arc<Vec<Spec>>,
    concurrency: usize,
    raw: bool,
) -> Result<Vec<Outcome>> {
    let next = Arc::new(AtomicUsize::new(0));
    let slots = Arc::new(Mutex::new(vec![None::<Outcome>; specs.len()]));
    let mut workers = Vec::new();
    for _ in 0..concurrency.min(specs.len()) {
        let (addr, specs) = (addr.to_string(), specs.clone());
        let (next, slots) = (next.clone(), slots.clone());
        workers.push(std::thread::spawn(move || loop {
            let i = next.fetch_add(1, Ordering::Relaxed);
            let Some(spec) = specs.get(i) else { break };
            let o = if raw { run_raw(&addr, spec) } else { run_http(&addr, spec) };
            slots.lock().unwrap()[i] = Some(o);
        }));
    }
    for w in workers {
        w.join().map_err(|_| anyhow::anyhow!("a loadgen worker thread panicked"))?;
    }
    let slots = Arc::try_unwrap(slots)
        .map_err(|_| anyhow::anyhow!("loadgen workers still hold the result slots"))?
        .into_inner()
        .map_err(|_| anyhow::anyhow!("result slots poisoned"))?;
    Ok(slots
        .into_iter()
        .map(|o| o.unwrap_or_else(|| Outcome::Failed("request was never run".to_string())))
        .collect())
}

fn connect(addr: &str) -> Result<TcpStream, String> {
    let s = TcpStream::connect(addr).map_err(|e| format!("connect {addr}: {e}"))?;
    let _ = s.set_nodelay(true);
    let _ = s.set_read_timeout(Some(Duration::from_secs(120)));
    let _ = s.set_write_timeout(Some(Duration::from_secs(120)));
    Ok(s)
}

/// The generate body; always `tokens` + `max_new` + `seed` so replays
/// are tokenizer-independent and the in-process verify is exact.
fn request_body(spec: &Spec) -> String {
    let mut w = JsonWriter::new();
    w.begin_object().key("tokens").begin_array();
    for &t in &spec.tokens {
        w.int(t as i64);
    }
    w.end_array().key("max_new").int(spec.max_new as i64).key("seed").int(spec.seed as i64);
    w.end_object();
    w.finish()
}

fn run_http(addr: &str, spec: &Spec) -> Outcome {
    let body = request_body(spec);
    let mut s = match connect(addr) {
        Ok(s) => s,
        Err(e) => return Outcome::Failed(e),
    };
    let req = format!(
        "POST /v1/generate HTTP/1.1\r\nHost: loadgen\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    if let Err(e) = s.write_all(req.as_bytes()) {
        return Outcome::Failed(format!("write: {e}"));
    }
    read_sse(&mut s)
}

/// Read an SSE response, stamping each token event as it arrives so
/// TTFT/ITL reflect what a real client observes (not server-side time).
fn read_sse(s: &mut TcpStream) -> Outcome {
    let start = Instant::now();
    let mut buf: Vec<u8> = Vec::new();
    let mut tmp = [0u8; 4096];
    let body_at = loop {
        if let Some(i) = find(&buf, b"\r\n\r\n") {
            break i + 4;
        }
        match s.read(&mut tmp) {
            Ok(0) => return Outcome::Failed("eof before response headers".to_string()),
            Ok(n) => buf.extend_from_slice(&tmp[..n]),
            Err(e) => return Outcome::Failed(format!("read: {e}")),
        }
    };
    let status = parse_status(&buf[..body_at]);
    if status != 200 {
        return Outcome::Rejected { status };
    }
    let mut tokens = Vec::new();
    let mut stamps = Vec::new();
    let mut done = None;
    let mut pos = body_at;
    'read: loop {
        while let Some(i) = find(&buf[pos..], b"\n\n") {
            let now = Instant::now();
            let chunk = &buf[pos..pos + i];
            match parse_event(chunk) {
                Event::Token(id) => {
                    tokens.push(id);
                    stamps.push(now);
                }
                Event::Done { error } => {
                    done = Some(error);
                    break 'read;
                }
                Event::Other => {}
            }
            pos += i + 2;
        }
        match s.read(&mut tmp) {
            Ok(0) => break,
            Ok(n) => buf.extend_from_slice(&tmp[..n]),
            Err(e) => return Outcome::Failed(format!("read: {e}")),
        }
    }
    finish_outcome(start, tokens, stamps, done)
}

fn run_raw(addr: &str, spec: &Spec) -> Outcome {
    let body = request_body(spec);
    let mut s = match connect(addr) {
        Ok(s) => s,
        Err(e) => return Outcome::Failed(e),
    };
    let mut req = Vec::with_capacity(8 + body.len());
    req.extend_from_slice(RAW_MAGIC);
    req.extend_from_slice(&(body.len() as u32).to_le_bytes());
    req.extend_from_slice(body.as_bytes());
    if let Err(e) = s.write_all(&req) {
        return Outcome::Failed(format!("write: {e}"));
    }
    let start = Instant::now();
    let mut tokens = Vec::new();
    let mut stamps = Vec::new();
    loop {
        let mut len4 = [0u8; 4];
        if let Err(e) = s.read_exact(&mut len4) {
            return Outcome::Failed(format!("frame header: {e}"));
        }
        // A pool-full connect is answered with an HTTP 503 even on a
        // raw-protocol socket (the server has not seen the magic yet) —
        // classify it instead of misreading "HTTP" as a frame length.
        if &len4 == b"HTTP" {
            return Outcome::Rejected { status: 503 };
        }
        let n = u32::from_le_bytes(len4) as usize;
        if n > 1 << 20 {
            return Outcome::Failed(format!("oversized frame ({n} bytes)"));
        }
        let mut frame = vec![0u8; n];
        if let Err(e) = s.read_exact(&mut frame) {
            return Outcome::Failed(format!("frame body: {e}"));
        }
        let now = Instant::now();
        let decoded = std::str::from_utf8(&frame).ok().and_then(|t| JsonValue::parse(t).ok());
        let Some(v) = decoded else {
            return Outcome::Failed("unparseable frame".to_string());
        };
        match v.get("type").and_then(JsonValue::as_str) {
            Some("token" | "done") => {
                let Some(inner) = v.get("frame") else {
                    return Outcome::Failed("frame payload missing".to_string());
                };
                match event_from_json(inner) {
                    Event::Token(id) => {
                        tokens.push(id);
                        stamps.push(now);
                    }
                    Event::Done { error } => {
                        return finish_outcome(start, tokens, stamps, Some(error));
                    }
                    Event::Other => {
                        return Outcome::Failed("unclassifiable frame".to_string());
                    }
                }
            }
            Some("error") => {
                let status = v.get("status").and_then(JsonValue::as_u64).unwrap_or(0) as u16;
                return Outcome::Rejected { status };
            }
            _ => return Outcome::Failed("unknown frame type".to_string()),
        }
    }
}

/// Fold the stream's collected events into an [`Outcome`].
fn finish_outcome(
    start: Instant,
    tokens: Vec<u32>,
    stamps: Vec<Instant>,
    done: Option<Option<String>>,
) -> Outcome {
    let Some(error) = done else {
        return Outcome::Failed("stream ended without a done event".to_string());
    };
    if let Some(e) = error {
        return Outcome::Failed(format!("server stream error: {e}"));
    }
    if tokens.is_empty() {
        return Outcome::Failed("done event with no tokens".to_string());
    }
    let ttft_us = stamps[0].duration_since(start).as_micros() as u64;
    let itl_us = stamps.windows(2).map(|w| w[1].duration_since(w[0]).as_micros() as u64).collect();
    Outcome::Ok { tokens, ttft_us, itl_us }
}

enum Event {
    Token(u32),
    /// Terminal event; payload is the server-reported error, if any.
    Done { error: Option<String> },
    Other,
}

/// One SSE chunk (`event:`/`data:` lines between blank lines); chunks
/// without a `data:` line (keep-alive comments) classify as Other.
fn parse_event(chunk: &[u8]) -> Event {
    let Ok(text) = std::str::from_utf8(chunk) else { return Event::Other };
    let Some(data) = text.lines().find_map(|l| l.strip_prefix("data: ")) else {
        return Event::Other;
    };
    let Ok(v) = JsonValue::parse(data) else { return Event::Other };
    event_from_json(&v)
}

/// Classify a decoded event payload (shared by SSE and raw framing —
/// the raw protocol nests the same objects under `frame`).
fn event_from_json(v: &JsonValue) -> Event {
    if let Some(id) = v.get("id").and_then(JsonValue::as_u64) {
        return Event::Token(id as u32);
    }
    if v.get("finish_reason").is_some() {
        let error = v.get("error").and_then(JsonValue::as_str).map(str::to_string);
        return Event::Done { error };
    }
    Event::Other
}

fn parse_status(head: &[u8]) -> u16 {
    std::str::from_utf8(head)
        .ok()
        .and_then(|t| t.split_whitespace().nth(1))
        .and_then(|code| code.parse().ok())
        .unwrap_or(0)
}

/// First byte offset of `needle` in `haystack`.
fn find(haystack: &[u8], needle: &[u8]) -> Option<usize> {
    haystack.windows(needle.len()).position(|w| w == needle)
}

fn fetch_metrics(addr: &str) -> Result<JsonValue> {
    let mut s = connect(addr).map_err(anyhow::Error::msg)?;
    s.write_all(b"GET /metrics HTTP/1.1\r\nHost: loadgen\r\n\r\n")?;
    let mut text = String::new();
    s.read_to_string(&mut text)?;
    let body = text.split("\r\n\r\n").nth(1).context("metrics response had no body")?;
    JsonValue::parse(body).map_err(anyhow::Error::msg)
}

fn post_drain(addr: &str) -> Result<()> {
    let mut s = connect(addr).map_err(anyhow::Error::msg)?;
    s.write_all(b"POST /admin/drain HTTP/1.1\r\nHost: loadgen\r\nContent-Length: 0\r\n\r\n")?;
    let mut text = String::new();
    s.read_to_string(&mut text)?;
    anyhow::ensure!(text.starts_with("HTTP/1.1 200"), "drain was refused: {text}");
    Ok(())
}

/// Pull (prefix_hits, prefix_lookups, counter line) out of a
/// `/metrics` response body.
fn summarize_server(metrics: Option<&JsonValue>) -> (u64, u64, String) {
    let Some(summary) = metrics.and_then(|m| m.get("summary")) else {
        return (0, 0, "unavailable".to_string());
    };
    let g = |k: &str| summary.get(k).and_then(JsonValue::as_u64).unwrap_or(0);
    let line = format!(
        "accepted {}, rejected_429 {}, cancelled_by_disconnect {}, drained {}",
        g("accepted"),
        g("rejected_429"),
        g("cancelled_by_disconnect"),
        g("drained")
    );
    (g("prefix_hits"), g("prefix_lookups"), line)
}

struct Agg {
    accepted: usize,
    rejected: usize,
    rejected_by: Vec<(u16, usize)>,
    failures: Vec<String>,
    tokens: usize,
    ttft_us: Vec<u64>,
    itl_us: Vec<u64>,
}

fn aggregate(outcomes: &[Outcome]) -> Agg {
    let mut agg = Agg {
        accepted: 0,
        rejected: 0,
        rejected_by: Vec::new(),
        failures: Vec::new(),
        tokens: 0,
        ttft_us: Vec::new(),
        itl_us: Vec::new(),
    };
    for o in outcomes {
        match o {
            Outcome::Ok { tokens, ttft_us, itl_us } => {
                agg.accepted += 1;
                agg.tokens += tokens.len();
                agg.ttft_us.push(*ttft_us);
                agg.itl_us.extend_from_slice(itl_us);
            }
            Outcome::Rejected { status } => {
                agg.rejected += 1;
                match agg.rejected_by.iter_mut().find(|(s, _)| *s == *status) {
                    Some((_, n)) => *n += 1,
                    None => agg.rejected_by.push((*status, 1)),
                }
            }
            Outcome::Failed(e) => agg.failures.push(e.clone()),
        }
    }
    agg.ttft_us.sort_unstable();
    agg.itl_us.sort_unstable();
    agg
}

/// Percentile over a sorted sample set (nearest-rank).
fn pct(sorted: &[u64], p: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let i = ((sorted.len() - 1) as f64 * p).round() as usize;
    sorted[i.min(sorted.len() - 1)]
}

/// Rebuild the engine a `serve` process with these flags is running
/// ([`build_setup`] is shared, flag for flag) and require every
/// accepted stream's wire tokens to be identical to in-process
/// decoding — the end-to-end parity gate behind the CI smoke.
fn verify_inprocess(args: &Args, specs: &[Spec], outcomes: &[Outcome]) -> Result<()> {
    println!("\nrebuilding the engine in-process to verify wire tokens …");
    let ServeSetup { kind, prefill_chunk, sweep_token_budget, .. } = build_setup(args)?;
    let router = Router::start(
        RouterConfig {
            n_workers: 1,
            max_batch: 4,
            strategy: Strategy::LeastLoaded,
            prefill_chunk,
            sweep_token_budget,
            ..Default::default()
        },
        move |_| Ok(kind.clone()),
    )?;
    let mut checked = 0usize;
    for (i, (spec, o)) in specs.iter().zip(outcomes).enumerate() {
        let Outcome::Ok { tokens, .. } = o else { continue };
        let mut params = sampling_params(args, spec.max_new)?;
        params.seed = spec.seed;
        let want = router.submit_with(spec.tokens.clone(), params, 0).collect()?.tokens;
        anyhow::ensure!(
            *tokens == want,
            "request {i}: wire tokens diverge from in-process decode ({tokens:?} vs {want:?})"
        );
        checked += 1;
    }
    router.shutdown();
    anyhow::ensure!(checked > 0, "--verify-inprocess: no accepted streams to check");
    println!("verify OK — {checked} streams token-identical to in-process decode");
    Ok(())
}
