//! `bpdq lint` — run the project-native static-analysis pass
//! ([`bpdq::analysis`]) over `rust/src/**/*.rs` and fail on findings.
//!
//! Flags:
//! * `--root <dir>`   source root to walk (default: `rust/src`, or `src`
//!   when invoked from inside `rust/`)
//! * `--config <file>` allowlist path (default: `lint.toml` next to the
//!   source root's parent, i.e. `rust/lint.toml`)
//! * `--list-rules`   print the rule registry and exit

use anyhow::{bail, Context, Result};
use bpdq::analysis::{apply_allowlist, lint_source, parse_allowlist, walk_rs_files, REGISTRY};
use bpdq::cli::Args;
use std::fs;
use std::path::PathBuf;

pub fn run(args: &Args) -> Result<()> {
    if args.has("list-rules") {
        for rule in REGISTRY {
            println!("{:4} {}", rule.id, rule.summary);
        }
        return Ok(());
    }

    let root = match args.get("root") {
        Some(r) => PathBuf::from(r),
        None => default_root()?,
    };
    let config = match args.get("config") {
        Some(c) => PathBuf::from(c),
        None => root.parent().unwrap_or(&root).join("lint.toml"),
    };

    let entries = if config.is_file() {
        let text = fs::read_to_string(&config)
            .with_context(|| format!("read allowlist {}", config.display()))?;
        parse_allowlist(&text).map_err(anyhow::Error::msg)?
    } else {
        Vec::new()
    };

    let files = walk_rs_files(&root)
        .with_context(|| format!("walk source root {}", root.display()))?;
    let mut findings = Vec::new();
    for path in &files {
        let src =
            fs::read_to_string(path).with_context(|| format!("read {}", path.display()))?;
        findings.extend(lint_source(&path.to_string_lossy(), &src));
    }

    let (kept, suppressed, used) = apply_allowlist(findings, &entries);

    for f in &kept {
        println!("{}:{}: [{}] ({}) {}", f.path, f.line, f.rule, f.func, f.msg);
        println!("    {}", f.excerpt);
    }
    for (entry, ok) in entries.iter().zip(&used) {
        if !ok {
            println!(
                "warning: unused allowlist entry at {}:{} ({} {} {})",
                config.display(),
                entry.line,
                entry.rule,
                entry.path,
                entry.func
            );
        }
    }
    println!(
        "lint: {} file(s), {} finding(s), {} allowlisted",
        files.len(),
        kept.len(),
        suppressed.len()
    );
    if !kept.is_empty() {
        bail!("lint: {} violation(s)", kept.len());
    }
    Ok(())
}

/// Resolve the source root relative to the working directory: the CI
/// job and the verify recipe both run from the workspace root, where
/// the tree lives at `rust/src`; `src` covers running from `rust/`.
fn default_root() -> Result<PathBuf> {
    for cand in ["rust/src", "src"] {
        let p = PathBuf::from(cand);
        if p.is_dir() {
            return Ok(p);
        }
    }
    bail!("no source root found (looked for rust/src and src); pass --root <dir>")
}
