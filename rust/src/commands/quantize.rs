//! `bpdq quantize` — quantize a `.tlm` checkpoint and report;
//! `bpdq eval` — run the benchmark battery on a checkpoint.

use anyhow::{Context, Result};
use bpdq::cli::Args;
use bpdq::data::{CorpusConfig, CorpusGen, Split, Tokenizer};
use bpdq::eval::{run_battery, EvalConfig};
use bpdq::io::tlm::TlmFile;
use bpdq::model::pipeline::quantize_model;
use bpdq::model::Model;
use bpdq::quant::{BcqConfig, BpdqConfig, QuantMethod, UniformConfig, VqConfig};
use std::path::Path;

/// Parse `--method/--bits/--group/--iters` into a QuantMethod.
pub fn parse_method(args: &Args) -> Result<QuantMethod> {
    let bits = args.get_usize("bits", 2).map_err(anyhow::Error::msg)? as u8;
    let group = args.get_usize("group", 64).map_err(anyhow::Error::msg)?;
    let iters = args.get_usize("iters", 10).map_err(anyhow::Error::msg)?;
    let uc = UniformConfig { bits, group_size: group, act_order: !args.has("no-act-order") };
    Ok(match args.get_or("method", "bpdq") {
        "fp16" => QuantMethod::Fp16,
        "rtn" => QuantMethod::Rtn(uc),
        "gptq" => QuantMethod::Gptq(uc),
        "awq" => QuantMethod::Awq(uc),
        "anybcq" => QuantMethod::AnyBcq(BcqConfig { bits, group_size: group, alt_iters: 6 }),
        "vptq" => QuantMethod::Vptq(VqConfig { bits, ..Default::default() }),
        "bpdq" => QuantMethod::Bpdq(BpdqConfig {
            k: bits,
            group_size: group,
            iters,
            ..Default::default()
        }),
        other => anyhow::bail!("unknown method `{other}`"),
    })
}

/// Load a checkpoint + the shared corpus/tokenizer context.
pub fn load_context(model_path: &str) -> Result<(Model, CorpusGen, Tokenizer)> {
    let tlm = TlmFile::load(Path::new(model_path))
        .with_context(|| format!("load checkpoint {model_path}"))?;
    let model = Model::from_tlm(&tlm)?;
    let gen = CorpusGen::new(CorpusConfig::default());
    let tok = Tokenizer::new();
    anyhow::ensure!(
        model.cfg.vocab_size == tok.vocab_size(),
        "checkpoint vocab {} != tokenizer vocab {}",
        model.cfg.vocab_size,
        tok.vocab_size()
    );
    Ok((model, gen, tok))
}

/// Calibration token sequences (same role the paper's 1024 C4 samples
/// play).
pub fn calib_seqs(gen: &CorpusGen, tok: &Tokenizer, n: usize, max_len: usize) -> Vec<Vec<u32>> {
    gen.token_docs(Split::Calib, n, tok)
        .into_iter()
        .map(|mut d| {
            d.truncate(max_len);
            d
        })
        .filter(|d| d.len() >= 8)
        .collect()
}

pub fn run_quantize(args: &Args) -> Result<()> {
    let model_path = args.get_or("model", "artifacts/tiny_small.tlm");
    let (model, gen, tok) = load_context(model_path)?;
    let method = parse_method(args)?;
    let n_calib = args.get_usize("calib", 64).map_err(anyhow::Error::msg)?;
    let calib = calib_seqs(&gen, &tok, n_calib, model.cfg.max_seq);

    println!("quantizing {model_path} with {} on {} calib seqs…", method.name(), calib.len());
    let qm = quantize_model(&model, &calib, &method)?;
    println!(
        "done in {:.1}s: BPW {:.3}, size {:.2} MiB (fp16 {:.2} MiB)",
        qm.quant_secs,
        qm.bits_per_weight(),
        qm.size_bytes() as f64 / (1 << 20) as f64,
        model.fp16_bytes() as f64 / (1 << 20) as f64,
    );
    let mean_err: f64 =
        qm.reports.iter().map(|r| r.output_err).sum::<f64>() / qm.reports.len() as f64;
    println!("mean per-linear output error: {mean_err:.4}");

    if let Some(out) = args.get("out") {
        qm.model.to_tlm().save(Path::new(out))?;
        println!("wrote dequantized checkpoint to {out}");
    }
    Ok(())
}

pub fn run_eval(args: &Args) -> Result<()> {
    let model_path = args.get_or("model", "artifacts/tiny_small.tlm");
    let (model, gen, tok) = load_context(model_path)?;
    let cfg = EvalConfig {
        n_ppl_docs: args.get_usize("ppl-docs", 64).map_err(anyhow::Error::msg)?,
        n_arith: args.get_usize("n-arith", 64).map_err(anyhow::Error::msg)?,
        n_choice: args.get_usize("n-choice", 64).map_err(anyhow::Error::msg)?,
        ..Default::default()
    };
    println!("evaluating {model_path}…");
    let s = run_battery(&model, &gen, &tok, &cfg);
    println!("ppl (Wiki2*)        : {:.3}", s.ppl);
    println!("arith EM (GSM8K*)   : {:.2}%", s.arith * 100.0);
    println!("fact 4-way (ARC*)   : {:.2}%", s.fact_choice * 100.0);
    println!("bool fact (BoolQ*)  : {:.2}%", s.bool_fact * 100.0);
    println!("contin. (HellaS*)   : {:.2}%", s.continuation * 100.0);
    println!("classify (TREC*)    : {:.2}%", s.classify * 100.0);
    Ok(())
}
