"""Build-time trainer for the tiny LMs (the paper-model stand-ins).

Trains a char-level decoder-only LM (see model.py) on the rust-generated
synthetic corpus with Adam + cosine decay, then exports `.tlm` weights
for the rust side. This is the "train a real model so quantization
damage is measurable" half of the substitution documented in DESIGN.md §3.

Usage:
    python -m compile.train_tiny --size small --steps 900 \
        --artifacts ../artifacts
"""

from __future__ import annotations

import argparse
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data_io, model
from .export_weights import write_tlm


def adam_init(params):
    z = lambda: jax.tree.map(jnp.zeros_like, params)
    return {"m": z(), "v": z(), "t": jnp.zeros((), jnp.int32)}


def adam_update(params, grads, state, lr, b1=0.9, b2=0.99, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    mhat = jax.tree.map(lambda m: m / (1 - b1 ** t.astype(jnp.float32)), m)
    vhat = jax.tree.map(lambda v: v / (1 - b2 ** t.astype(jnp.float32)), v)
    new = jax.tree.map(lambda p, mh, vh: p - lr * mh / (jnp.sqrt(vh) + eps),
                       params, mhat, vhat)
    return new, {"m": m, "v": v, "t": t}


def make_batches(tokens: np.ndarray, batch: int, seq: int, rng: np.random.Generator):
    """Random contiguous windows (+1 for the shifted target)."""
    n = len(tokens) - seq - 1
    while True:
        idx = rng.integers(0, n, size=batch)
        yield np.stack([tokens[i:i + seq + 1] for i in idx])


def train(size: str, steps: int, batch: int, seq: int, lr: float,
          artifacts: pathlib.Path, seed: int = 0,
          n_kv_heads: int | None = None) -> pathlib.Path:
    vocab = data_io.load_vocab(artifacts)
    tokens = data_io.load_corpus_tokens(artifacts, "corpus_train.txt", vocab)
    print(f"[train] corpus: {len(tokens)} tokens, vocab {len(vocab)}")

    mk = model.tiny_small if size == "small" else model.tiny_large
    cfg = mk(len(vocab), n_kv_heads)
    params = model.init_params(cfg, jax.random.PRNGKey(seed))
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"[train] size={size}: {n_params/1e6:.2f}M params, {steps} steps, "
          f"batch {batch} × seq {seq}, kv heads {cfg['n_kv_heads']}/{cfg['n_heads']}")

    opt = adam_init(params)
    warmup = max(20, steps // 20)

    @jax.jit
    def step_fn(params, opt, toks, lr_now):
        mask = jnp.ones_like(toks, jnp.float32)
        loss, grads = jax.value_and_grad(model.loss_fn)(params, cfg, toks, mask)
        params, opt = adam_update(params, grads, opt, lr_now)
        return params, opt, loss

    rng = np.random.default_rng(seed + 1)
    batches = make_batches(tokens, batch, seq, rng)
    t0 = time.time()
    losses = []
    for s in range(steps):
        frac = s / max(1, steps)
        lr_now = lr * min(1.0, (s + 1) / warmup) * (0.5 * (1 + np.cos(np.pi * frac)))
        toks = jnp.asarray(next(batches))
        params, opt, loss = step_fn(params, opt, toks, jnp.float32(lr_now))
        losses.append(float(loss))
        if s % 50 == 0 or s == steps - 1:
            dt = time.time() - t0
            print(f"[train] step {s:5d}  loss {float(loss):.4f}  "
                  f"({dt:.1f}s, {dt/max(1,s+1):.2f}s/step)", flush=True)

    # GQA checkpoints get their own artifact name so the stock MHA
    # tiny_{size}.tlm consumers keep working.
    gqa = cfg["n_kv_heads"] != cfg["n_heads"]
    stem = f"tiny_{size}_kv{cfg['n_kv_heads']}" if gqa else f"tiny_{size}"
    out = artifacts / f"{stem}.tlm"
    write_tlm(out, cfg, params)
    # loss curve for EXPERIMENTS.md
    curve = artifacts / f"{stem}_loss.txt"
    curve.write_text("\n".join(f"{i} {l:.5f}" for i, l in enumerate(losses)) + "\n")
    print(f"[train] wrote {out} (final loss {losses[-1]:.4f})")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", choices=["small", "large"], default="small")
    ap.add_argument("--steps", type=int, default=900)
    ap.add_argument("--batch", type=int, default=24)
    ap.add_argument("--seq", type=int, default=96)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--artifacts", default="../artifacts")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--kv-heads", type=int, default=0,
                    help="K/V heads for grouped-query attention "
                         "(0 = n_heads, plain MHA)")
    args = ap.parse_args()
    train(args.size, args.steps, args.batch, args.seq, args.lr,
          pathlib.Path(args.artifacts), args.seed,
          args.kv_heads or None)


if __name__ == "__main__":
    main()
