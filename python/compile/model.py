"""L2 — the JAX tiny-LM, mirrored exactly against ``rust/src/model``.

Architecture contract (any change must be mirrored in rust/src/model):
  * token embedding, no scaling;
  * per block: RMSNorm(eps 1e-5) -> causal attention (wq,wk,wv,wo; RoPE
    rotate-half, base 10000; grouped-query when ``n_kv_heads < n_heads``
    — wk/wv project to ``kv_dim = n_kv_heads * head_dim`` and each group
    of ``n_heads // n_kv_heads`` query heads shares one K/V head) ->
    residual -> RMSNorm -> SwiGLU (w1=up, w3=gate, w2=down) -> residual;
  * final RMSNorm -> untied lm_head.

Weights live in a flat dict keyed like the ``.tlm`` tensors ("embed",
"l0.wq", ..., "norm_f", "lm_head"); all linears are (d_out, d_in) so the
forward is ``x @ W.T`` — identical to the rust convention.

This module is build-time only: it trains (see train_tiny.py) and lowers
(see aot.py). Python never runs on the request path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

RMS_EPS = 1e-5
ROPE_BASE = 10_000.0


def config(vocab_size: int, d_model: int, n_layers: int, n_heads: int,
           d_ff: int, max_seq: int, n_kv_heads: int | None = None) -> dict:
    """``n_kv_heads`` defaults to ``n_heads`` (plain MHA); a proper
    divisor turns on grouped-query attention."""
    n_kv = n_heads if n_kv_heads is None else n_kv_heads
    assert d_model % n_heads == 0
    assert n_kv > 0 and n_heads % n_kv == 0, \
        f"n_kv_heads ({n_kv}) must divide n_heads ({n_heads})"
    return dict(vocab_size=vocab_size, d_model=d_model, n_layers=n_layers,
                n_heads=n_heads, n_kv_heads=n_kv, d_ff=d_ff, max_seq=max_seq)


def tiny_small(vocab_size: int, n_kv_heads: int | None = None) -> dict:
    """≈0.8M params — mirrors ModelConfig::tiny_small."""
    return config(vocab_size, 128, 4, 4, 344, 256, n_kv_heads)


def tiny_large(vocab_size: int, n_kv_heads: int | None = None) -> dict:
    """≈3.4M params — mirrors ModelConfig::tiny_large."""
    return config(vocab_size, 256, 6, 8, 688, 256, n_kv_heads)


def kv_dim(cfg: dict) -> int:
    """Width of the K/V projections and of one cached KV row."""
    return cfg.get("n_kv_heads", cfg["n_heads"]) * (cfg["d_model"] // cfg["n_heads"])


def init_params(cfg: dict, key: jax.Array) -> dict:
    """He-ish init; names match the .tlm tensor set exactly."""
    v, d, ff = cfg["vocab_size"], cfg["d_model"], cfg["d_ff"]
    params = {}
    n_mats = 3 + 7 * cfg["n_layers"]
    keys = jax.random.split(key, n_mats)
    ki = iter(keys)

    def mat(k, rows, cols, scale):
        return (jax.random.normal(k, (rows, cols), jnp.float32) * scale)

    params["embed"] = mat(next(ki), v, d, 0.02)
    params["lm_head"] = mat(next(ki), v, d, 0.02)
    params["norm_f"] = jnp.ones((d,), jnp.float32)
    _ = next(ki)
    kvd = kv_dim(cfg)
    for l in range(cfg["n_layers"]):
        s = (1.0 / d) ** 0.5
        s2 = (1.0 / ff) ** 0.5
        sub = jax.random.split(jax.random.fold_in(key, 1000 + l), 7)
        params[f"l{l}.norm1"] = jnp.ones((d,), jnp.float32)
        params[f"l{l}.norm2"] = jnp.ones((d,), jnp.float32)
        params[f"l{l}.wq"] = mat(sub[0], d, d, s)
        params[f"l{l}.wk"] = mat(sub[1], kvd, d, s)
        params[f"l{l}.wv"] = mat(sub[2], kvd, d, s)
        params[f"l{l}.wo"] = mat(sub[3], d, d, s)
        params[f"l{l}.w1"] = mat(sub[4], ff, d, s)
        params[f"l{l}.w3"] = mat(sub[5], ff, d, s)
        params[f"l{l}.w2"] = mat(sub[6], d, ff, s2)
    return params


def rmsnorm(x: jax.Array, gain: jax.Array) -> jax.Array:
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + RMS_EPS) * gain


def rope_tables(seq: int, head_dim: int, offset=0):
    half = head_dim // 2
    pos = jnp.arange(seq)[:, None] + offset          # (seq, 1)
    i = jnp.arange(half)[None, :]                    # (1, half)
    theta = pos / (ROPE_BASE ** (2.0 * i / head_dim))
    return jnp.cos(theta), jnp.sin(theta)            # each (seq, half)


def rope_apply(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (..., seq, n_heads, head_dim); rotate-half convention."""
    half = x.shape[-1] // 2
    a, b = x[..., :half], x[..., half:]
    cos = cos[..., :, None, :]   # broadcast over heads
    sin = sin[..., :, None, :]
    return jnp.concatenate([a * cos - b * sin, b * cos + a * sin], axis=-1)


def block_forward(params: dict, cfg: dict, l: int, h: jax.Array) -> jax.Array:
    """h: (seq, d) -> (seq, d). Full-sequence causal block (grouped-query
    when n_kv_heads < n_heads: K/V heads are repeated across their query
    group, matching the rust ``hh / kv_group`` head mapping)."""
    d, nh = cfg["d_model"], cfg["n_heads"]
    nkv = cfg.get("n_kv_heads", nh)
    grp = nh // nkv
    hd = d // nh
    seq = h.shape[0]
    p = lambda n: params[f"l{l}.{n}"]

    x = rmsnorm(h, p("norm1"))
    q = (x @ p("wq").T).reshape(seq, nh, hd)
    k = (x @ p("wk").T).reshape(seq, nkv, hd)
    v = (x @ p("wv").T).reshape(seq, nkv, hd)
    cos, sin = rope_tables(seq, hd)
    q = rope_apply(q, cos, sin)
    k = rope_apply(k, cos, sin)
    if grp > 1:
        # kv head j serves query heads j*grp .. (j+1)*grp — the same
        # mapping as rust's kvh = hh / group.
        k = jnp.repeat(k, grp, axis=1)
        v = jnp.repeat(v, grp, axis=1)
    scores = jnp.einsum("qhd,khd->hqk", q, k) / jnp.sqrt(jnp.float32(hd))
    mask = jnp.tril(jnp.ones((seq, seq), bool))
    scores = jnp.where(mask[None, :, :], scores, -1e30)
    attn = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("hqk,khd->qhd", attn, v).reshape(seq, d)
    h = h + ctx @ p("wo").T

    x = rmsnorm(h, p("norm2"))
    up = x @ p("w1").T
    gate = x @ p("w3").T
    h = h + (up * jax.nn.silu(gate)) @ p("w2").T
    return h


def forward(params: dict, cfg: dict, tokens: jax.Array) -> jax.Array:
    """tokens: (seq,) int32 -> logits (seq, vocab)."""
    h = params["embed"][tokens]
    for l in range(cfg["n_layers"]):
        h = block_forward(params, cfg, l, h)
    h = rmsnorm(h, params["norm_f"])
    return h @ params["lm_head"].T


def forward_batch(params: dict, cfg: dict, tokens: jax.Array) -> jax.Array:
    """tokens: (batch, seq) -> (batch, seq, vocab)."""
    return jax.vmap(lambda t: forward(params, cfg, t))(tokens)


def loss_fn(params: dict, cfg: dict, tokens: jax.Array, mask: jax.Array) -> jax.Array:
    """Next-token cross entropy. tokens (b, s); mask (b, s) 1.0 where the
    *target* position is real (not padding)."""
    logits = forward_batch(params, cfg, tokens[:, :-1])
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    m = mask[:, 1:]
    return jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)


# ---------------------------------------------------------------------------
# Incremental decode step (the shape that gets AOT-lowered for the rust
# serving engine). The KV cache is functional state threaded through.
# ---------------------------------------------------------------------------

def decode_step(params: dict, cfg: dict, token: jax.Array, pos: jax.Array,
                kcache: jax.Array, vcache: jax.Array):
    """One-token decode.

    token: () int32; pos: () int32;
    kcache/vcache: (n_layers, cache_len, kv_dim) — ``kv_dim``-wide, so a
    GQA checkpoint threads caches ``n_heads // n_kv_heads`` smaller than
    the legacy d_model-wide layout (the rust engine reads the width from
    the ``.meta`` sidecar, see aot.py).
    Returns (logits (vocab,), kcache', vcache').
    """
    d, nh = cfg["d_model"], cfg["n_heads"]
    nkv = cfg.get("n_kv_heads", nh)
    grp = nh // nkv
    hd = d // nh
    kvd = nkv * hd
    cache_len = kcache.shape[1]
    assert kcache.shape[2] == kvd, f"cache width {kcache.shape[2]} != kv_dim {kvd}"
    h = params["embed"][token]

    half = hd // 2
    i = jnp.arange(half)
    theta = pos.astype(jnp.float32) / (ROPE_BASE ** (2.0 * i / hd))
    cos, sin = jnp.cos(theta), jnp.sin(theta)

    def rot(x):  # x: (heads, hd)
        a, b = x[:, :half], x[:, half:]
        return jnp.concatenate([a * cos - b * sin, b * cos + a * sin], axis=-1)

    for l in range(cfg["n_layers"]):
        p = lambda n: params[f"l{l}.{n}"]
        x = rmsnorm(h, p("norm1"))
        q = rot((p("wq") @ x).reshape(nh, hd))
        k = rot((p("wk") @ x).reshape(nkv, hd))
        v = (p("wv") @ x).reshape(nkv, hd)
        kcache = jax.lax.dynamic_update_slice(kcache, k.reshape(1, 1, kvd), (l, pos, 0))
        vcache = jax.lax.dynamic_update_slice(vcache, v.reshape(1, 1, kvd), (l, pos, 0))
        kl = kcache[l].reshape(cache_len, nkv, hd)
        vl = vcache[l].reshape(cache_len, nkv, hd)
        if grp > 1:
            kl = jnp.repeat(kl, grp, axis=1)  # (cache_len, nh, hd)
            vl = jnp.repeat(vl, grp, axis=1)
        scores = jnp.einsum("hd,thd->ht", q, kl) / jnp.sqrt(jnp.float32(hd))
        valid = jnp.arange(cache_len) <= pos
        scores = jnp.where(valid[None, :], scores, -1e30)
        attn = jax.nn.softmax(scores, axis=-1)
        ctx = jnp.einsum("ht,thd->hd", attn, vl).reshape(d)
        h = h + p("wo") @ ctx

        x = rmsnorm(h, p("norm2"))
        up = p("w1") @ x
        gate = p("w3") @ x
        h = h + p("w2") @ (up * jax.nn.silu(gate))

    h = rmsnorm(h, params["norm_f"])
    return params["lm_head"] @ h, kcache, vcache


# ---------------------------------------------------------------------------
# Quantized forward variants calling the L1 kernels (used by aot.py to lower
# the BPDQ serving linear + a quantized decode step into HLO).
# ---------------------------------------------------------------------------

def bpdq_linear(x, plane_bytes, coeffs, group_size: int, use_pallas=True):
    """y = Ŵ x where Ŵ is BPDQ-packed. See kernels/bpdq_lut.py."""
    from .kernels import bpdq_lut
    if use_pallas:
        return bpdq_lut.lut_gemv(x, plane_bytes, coeffs, group_size)
    from .kernels import ref
    return ref.lut_gemv_ref(x, plane_bytes, coeffs, group_size)
