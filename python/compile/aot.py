"""AOT lowering: JAX (L2) + Pallas (L1) → HLO **text** artifacts for the
rust PJRT runtime (L3).

HLO text — NOT ``lowered.compiler_ir("hlo").as_serialized_hlo_module_proto()``
— is the interchange format: jax ≥ 0.5 emits protos with 64-bit
instruction ids which xla_extension 0.5.1 (the version behind the
published `xla` 0.1.6 crate) rejects (`proto.id() <= INT_MAX`). The text
parser reassigns ids, so text round-trips cleanly. See
/opt/xla-example/README.md.

Artifacts produced (all shapes fixed at lowering time; the rust runtime
compiles each once and caches the executable):

* ``bpdq_gemv.hlo.txt``    — the Pallas LUT-GEMV serving kernel
  (d_in=128, d_out=128, k=2, g=64 — the tiny_small attention shape);
* ``dequant_gemv.hlo.txt`` — the dequantize-then-matmul baseline kernel,
  same shape;
* ``decode_step.hlo.txt``  — a full single-token decode step of the
  trained tiny_small model (weights baked in as constants), KV cache
  threaded functionally: (token, pos, kcache, vcache) → (logits, k', v').
  Caches are ``kv_dim``-wide (GQA-aware); the sidecar ``decode_step.meta``
  records ``kv_dim`` so the rust engine can shape its cache literals —
  artifacts without that line predate GQA and are treated as
  d_model-wide MHA-only by the engine.

Python runs once at build time; the rust binary is self-contained after
`make artifacts`.
"""

from __future__ import annotations

import argparse
import functools
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model
from .export_weights import read_tlm
from .kernels import bpdq_lut, dequant


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True: the decode step bakes the trained
    # weights in as constants; the default printer elides them as
    # `constant({...})`, which the HLO parser then reads as ZEROS —
    # silently wrong numerics on the rust side.
    return comp.as_hlo_text(print_large_constants=True)


def lower_kernels(out_dir: pathlib.Path, d_in=128, d_out=128, k=2, g=64):
    """Lower both L1 kernels at the serving shape."""
    x = jax.ShapeDtypeStruct((d_in,), jnp.float32)
    pb = jax.ShapeDtypeStruct((k, d_out, d_in // 8), jnp.uint8)
    cf = jax.ShapeDtypeStruct((k + 1, d_out, d_in // g), jnp.float32)

    for name, fn in [
        ("bpdq_gemv", functools.partial(bpdq_lut.lut_gemv, group_size=g)),
        ("dequant_gemv", functools.partial(dequant.dequant_gemv, group_size=g)),
    ]:
        lowered = jax.jit(lambda x, pb, cf, fn=fn: (fn(x, pb, cf),)).lower(x, pb, cf)
        text = to_hlo_text(lowered)
        path = out_dir / f"{name}.hlo.txt"
        path.write_text(text)
        print(f"[aot] wrote {path} ({len(text)} chars, shape "
              f"d_in={d_in} d_out={d_out} k={k} g={g})")


def lower_decode_step(out_dir: pathlib.Path, ckpt: pathlib.Path, cache_len=256):
    """Lower the trained model's single-token decode step with weights
    baked in as HLO constants. The KV caches are ``kv_dim``-wide —
    exactly ``n_heads // n_kv_heads`` smaller than the legacy
    d_model-wide layout for GQA checkpoints — and the ``.meta`` sidecar
    records the width for the rust engine."""
    cfg, raw = read_tlm(ckpt)
    params = {k: jnp.asarray(v) for k, v in raw.items()}
    mcfg = model.config(cfg["vocab_size"], cfg["d_model"], cfg["n_layers"],
                        cfg["n_heads"], cfg["d_ff"], cfg["max_seq"],
                        n_kv_heads=cfg.get("n_kv_heads"))
    nl, d = mcfg["n_layers"], mcfg["d_model"]
    kvd = model.kv_dim(mcfg)

    def step(token, pos, kcache, vcache):
        return model.decode_step(params, mcfg, token, pos, kcache, vcache)

    args = (
        jax.ShapeDtypeStruct((), jnp.int32),
        jax.ShapeDtypeStruct((), jnp.int32),
        jax.ShapeDtypeStruct((nl, cache_len, kvd), jnp.float32),
        jax.ShapeDtypeStruct((nl, cache_len, kvd), jnp.float32),
    )
    lowered = jax.jit(step).lower(*args)
    text = to_hlo_text(lowered)
    path = out_dir / "decode_step.hlo.txt"
    path.write_text(text)
    meta = out_dir / "decode_step.meta"
    meta.write_text(
        f"vocab_size {mcfg['vocab_size']}\nd_model {d}\nn_layers {nl}\n"
        f"cache_len {cache_len}\n"
        f"n_heads {mcfg['n_heads']}\nn_kv_heads {mcfg['n_kv_heads']}\n"
        f"kv_dim {kvd}\n"
    )
    print(f"[aot] wrote {path} ({len(text)} chars, cache_len={cache_len}, "
          f"kv_dim={kvd})")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--ckpt", default=None,
                    help=".tlm checkpoint for decode_step (default: "
                         "<out>/tiny_small.tlm if present)")
    ap.add_argument("--cache-len", type=int, default=256)
    ap.add_argument("--skip-decode", action="store_true")
    args = ap.parse_args()
    out_dir = pathlib.Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    lower_kernels(out_dir)
    ckpt = pathlib.Path(args.ckpt) if args.ckpt else out_dir / "tiny_small.tlm"
    if args.skip_decode:
        print("[aot] skipping decode_step")
    elif ckpt.exists():
        lower_decode_step(out_dir, ckpt, args.cache_len)
    else:
        print(f"[aot] {ckpt} missing — run train_tiny first; decode_step skipped")


if __name__ == "__main__":
    main()
