"""L1 — the bit-plane LUT-GEMV Pallas kernel (paper §4.3 / LUT-GEMM,
Park et al. 2022), adapted from CUDA warps to the TPU execution model.

Algorithm (per output tile):
  1. Build the subset-sum LUT over 8-wide activation chunks:
     ``LUT[c, p] = Σ_i x[8c+i]·bit(p, i)`` — expressed as the matmul
     ``x_chunks(nc,8) @ P.T(8,256)``, i.e. **MXU-shaped** instead of the
     CUDA shared-memory scatter (DESIGN.md §Hardware-Adaptation).
  2. Gather per (plane, row, chunk): ``LUT[c, byte[i,r,c]]`` — a lane
     gather (VPU) replacing the warp ballot.
  3. Reduce chunks within each group and combine with the scalar
     coefficients: ``y_r = Σ_g c₀ S_g + Σ_i cᵢ · partialᵢ`` where
     ``S_g`` is the group's activation sum (the bias term of the
     variable grid).

The grid is 1-D over output-row tiles; the x vector and its LUT live in
VMEM once per tile (BlockSpec maps the full x block to every tile).
``interpret=True`` everywhere — the CPU PJRT plugin cannot run Mosaic
custom-calls; real-TPU numbers are estimated in DESIGN.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

def _patterns() -> jnp.ndarray:
    """Binary pattern table P[p, i] = bit i of p — built from iota inside
    the kernel (pallas forbids captured constants)."""
    p = jax.lax.iota(jnp.uint32, 256)[:, None]
    i = jax.lax.iota(jnp.uint32, 8)[None, :]
    return ((p >> i) & 1).astype(jnp.float32)


def _pick_tile(d_out: int, max_tile: int = 64) -> int:
    """Largest divisor of d_out not exceeding max_tile."""
    for t in range(min(max_tile, d_out), 0, -1):
        if d_out % t == 0:
            return t
    return 1


def _lut_gemv_kernel(x_ref, bytes_ref, coeffs_ref, y_ref, *, group_size: int):
    """One output tile.

    x_ref:      (d_in,)            — the full activation vector
    bytes_ref:  (k, T, d_in//8)    — packed planes for this row tile
    coeffs_ref: (k+1, T, n_groups) — scalar coefficients for this tile
    y_ref:      (T,)
    """
    x = x_ref[...]
    pb = bytes_ref[...]
    cf = coeffs_ref[...]
    k, t, nc = pb.shape
    n_groups = cf.shape[2]
    cpg = group_size // 8  # chunks per group

    # (1) subset-sum LUT via matmul (MXU-shaped)
    xc = x.reshape(nc, 8)
    lut = xc @ _patterns().T                                 # (nc, 256)

    # group activation sums for the bias term
    s_g = xc.reshape(n_groups, cpg * 8).sum(axis=1)          # (n_groups,)

    # (2) gather LUT entries per (plane, row, chunk)
    idx = pb.astype(jnp.int32)                               # (k, T, nc)
    lut_b = jnp.broadcast_to(lut, (k, t, nc, 256))
    part = jnp.take_along_axis(lut_b, idx[..., None], axis=-1)[..., 0]  # (k,T,nc)

    # (3) reduce chunks per group, combine with coefficients
    part_g = part.reshape(k, t, n_groups, cpg).sum(axis=-1)  # (k,T,ng)
    y = cf[0] @ s_g                                          # (T,) bias term
    y = y + jnp.einsum("ktg,ktg->t", cf[1:], part_g)
    y_ref[...] = y


def lut_gemv(x: jnp.ndarray, plane_bytes: jnp.ndarray, coeffs: jnp.ndarray,
             group_size: int) -> jnp.ndarray:
    """y = Ŵ x with Ŵ BPDQ-packed. Shapes per kernels/ref.py."""
    d_in = x.shape[0]
    k, d_out, nc = plane_bytes.shape
    ng = coeffs.shape[2]
    assert nc * 8 == d_in, "d_in must be a multiple of 8"
    assert group_size % 8 == 0, "group_size must be a multiple of 8"
    assert ng * group_size == d_in, "d_in must be a multiple of group_size"
    assert coeffs.shape == (k + 1, d_out, ng)

    t = _pick_tile(d_out)
    kernel = functools.partial(_lut_gemv_kernel, group_size=group_size)
    return pl.pallas_call(
        kernel,
        grid=(d_out // t,),
        in_specs=[
            pl.BlockSpec((d_in,), lambda i: (0,)),
            pl.BlockSpec((k, t, nc), lambda i: (0, i, 0)),
            pl.BlockSpec((k + 1, t, ng), lambda i: (0, i, 0)),
        ],
        out_specs=pl.BlockSpec((t,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((d_out,), jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32), plane_bytes, coeffs.astype(jnp.float32))
