"""L1 — dequantize-then-matmul Pallas kernel (the baseline the LUT kernel
is compared against in Table 3, and the building block of the quantized
decode step lowered by aot.py).

Per output tile: unpack the bit-planes of the tile's rows, reconstruct
``Ŵ = REP(C₀) + Σ REP(Cᵢ)⊙Bᵢ`` in VMEM, then one (T, d_in)×(d_in,) matvec
on the MXU. HBM traffic is the *packed* bits (k·d_in/8 bytes per row +
coefficients), so the memory-bound decode regime sees the paper's
bits-per-weight reduction directly.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .bpdq_lut import _pick_tile


def _dequant_gemv_kernel(x_ref, bytes_ref, coeffs_ref, y_ref, *, group_size: int):
    x = x_ref[...]                       # (d_in,)
    pb = bytes_ref[...]                  # (k, T, nc)
    cf = coeffs_ref[...]                 # (k+1, T, ng)
    k, t, nc = pb.shape
    d_in = x.shape[0]

    shifts = jnp.arange(8, dtype=jnp.uint8)
    bits = ((pb[..., None] >> shifts) & 1).astype(jnp.float32)  # (k,T,nc,8)
    bits = bits.reshape(k, t, d_in)

    rep = jnp.repeat(cf, group_size, axis=2)[:, :, :d_in]       # (k+1,T,d_in)
    w = rep[0] + jnp.einsum("ktd,ktd->td", rep[1:], bits)       # (T, d_in)
    y_ref[...] = w @ x


def dequant_gemv(x: jnp.ndarray, plane_bytes: jnp.ndarray, coeffs: jnp.ndarray,
                 group_size: int) -> jnp.ndarray:
    """y = Ŵ x via in-VMEM dequantization."""
    d_in = x.shape[0]
    k, d_out, nc = plane_bytes.shape
    ng = coeffs.shape[2]
    assert nc * 8 == d_in and ng * group_size == d_in

    t = _pick_tile(d_out)
    kernel = functools.partial(_dequant_gemv_kernel, group_size=group_size)
    return pl.pallas_call(
        kernel,
        grid=(d_out // t,),
        in_specs=[
            pl.BlockSpec((d_in,), lambda i: (0,)),
            pl.BlockSpec((k, t, nc), lambda i: (0, i, 0)),
            pl.BlockSpec((k + 1, t, ng), lambda i: (0, i, 0)),
        ],
        out_specs=pl.BlockSpec((t,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((d_out,), jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32), plane_bytes, coeffs.astype(jnp.float32))
