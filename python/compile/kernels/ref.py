"""Pure-jnp oracles for the L1 kernels.

These are the correctness ground truth: pytest sweeps the Pallas kernels
against them (hypothesis over shapes/k/group-size), and the rust LUT
engine is validated against the same packed format through the AOT
round-trip.

Packed format (shared with rust `quant::packing` and the kernels):
  * ``plane_bytes``: (k, d_out, d_in//8) uint8 — bit ``j%8`` of byte
    ``j//8`` is plane value at input column ``j`` (little-endian within
    the byte, matching the rust u32 packing truncated to bytes);
  * ``coeffs``: (k+1, d_out, d_in//group_size) float32 — index 0 is the
    group bias C₀, index i≥1 the scale of plane i (paper Eq. 1).
"""

from __future__ import annotations

import jax.numpy as jnp


def unpack_planes(plane_bytes: jnp.ndarray, d_in: int) -> jnp.ndarray:
    """(k, d_out, d_in//8) uint8 -> (k, d_out, d_in) float32 in {0,1}."""
    k, d_out, n_chunks = plane_bytes.shape
    assert n_chunks * 8 == d_in, f"d_in {d_in} != 8*{n_chunks}"
    shifts = jnp.arange(8, dtype=jnp.uint8)
    bits = (plane_bytes[..., None] >> shifts) & 1          # (k, d_out, nc, 8)
    return bits.reshape(k, d_out, d_in).astype(jnp.float32)


def pack_planes(planes: jnp.ndarray) -> jnp.ndarray:
    """(k, d_out, d_in) {0,1} -> (k, d_out, d_in//8) uint8."""
    k, d_out, d_in = planes.shape
    assert d_in % 8 == 0
    b = planes.reshape(k, d_out, d_in // 8, 8).astype(jnp.uint8)
    weights = (1 << jnp.arange(8, dtype=jnp.uint32)).astype(jnp.uint32)
    return jnp.sum(b.astype(jnp.uint32) * weights, axis=-1).astype(jnp.uint8)


def dequant_ref(plane_bytes: jnp.ndarray, coeffs: jnp.ndarray,
                group_size: int, d_in: int) -> jnp.ndarray:
    """Reconstruct Ŵ = REP(C₀) + Σᵢ REP(Cᵢ) ⊙ Bᵢ (paper Eq. 1)."""
    k, d_out, _ = plane_bytes.shape
    planes = unpack_planes(plane_bytes, d_in)              # (k, d_out, d_in)
    rep = jnp.repeat(coeffs, group_size, axis=2)[:, :, :d_in]  # (k+1, d_out, d_in)
    w = rep[0]
    for i in range(k):
        w = w + rep[i + 1] * planes[i]
    return w


def lut_gemv_ref(x: jnp.ndarray, plane_bytes: jnp.ndarray,
                 coeffs: jnp.ndarray, group_size: int) -> jnp.ndarray:
    """y = Ŵ @ x — the oracle the Pallas LUT kernel must match."""
    w = dequant_ref(plane_bytes, coeffs, group_size, x.shape[0])
    return w @ x
