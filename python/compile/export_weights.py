"""Write trained JAX params to the `.tlm` format rust loads.

Byte-for-byte mirror of `rust/src/io/tlm.rs` (little-endian, see that
module for the layout).
"""

from __future__ import annotations

import pathlib
import struct

import numpy as np

MAGIC = b"TLM1"


def write_tlm(path: pathlib.Path, cfg: dict, params: dict) -> None:
    tensors = {}
    for name, arr in params.items():
        a = np.asarray(arr, dtype=np.float32)
        if a.ndim == 1:
            a = a.reshape(1, -1)
        assert a.ndim == 2, f"{name}: rank {a.ndim}"
        tensors[name] = a

    with open(path, "wb") as f:
        f.write(MAGIC)
        for key in ("vocab_size", "d_model", "n_layers", "n_heads", "d_ff", "max_seq"):
            f.write(struct.pack("<I", cfg[key]))
        f.write(struct.pack("<I", len(tensors)))
        for name in sorted(tensors):  # BTreeMap order on the rust side
            a = tensors[name]
            nb = name.encode()
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<II", a.shape[0], a.shape[1]))
            f.write(a.astype("<f4").tobytes())


def read_tlm(path: pathlib.Path):
    """Reader (round-trip tests + loading checkpoints back for AOT)."""
    with open(path, "rb") as f:
        assert f.read(4) == MAGIC, "bad magic"
        keys = ("vocab_size", "d_model", "n_layers", "n_heads", "d_ff", "max_seq")
        cfg = {k: struct.unpack("<I", f.read(4))[0] for k in keys}
        (n,) = struct.unpack("<I", f.read(4))
        params = {}
        for _ in range(n):
            (ln,) = struct.unpack("<I", f.read(4))
            name = f.read(ln).decode()
            rows, cols = struct.unpack("<II", f.read(8))
            data = np.frombuffer(f.read(rows * cols * 4), dtype="<f4").reshape(rows, cols)
            params[name] = data.copy()
        # squeeze the vectors back
        for k in list(params):
            if params[k].shape[0] == 1 and ("norm" in k):
                params[k] = params[k][0]
    return cfg, params
