"""Write trained JAX params to the `.tlm` format rust loads.

Byte-for-byte mirror of `rust/src/io/tlm.rs` (little-endian, see that
module for the layout). Two header revisions:

* ``TLM1`` — legacy MHA header (6 u32 fields, no ``n_kv_heads``);
* ``TLM2`` — GQA-aware header (7 u32 fields, ``n_kv_heads`` after
  ``n_heads``).

Like the rust writer, models with ``n_kv_heads == n_heads`` (or with no
``n_kv_heads`` in the config at all) serialize as ``TLM1`` so pre-GQA
consumers keep working; readers accept both and default
``n_kv_heads = n_heads`` for legacy files.
"""

from __future__ import annotations

import pathlib
import struct

import numpy as np

MAGIC = b"TLM1"
MAGIC_V2 = b"TLM2"

_V1_KEYS = ("vocab_size", "d_model", "n_layers", "n_heads", "d_ff", "max_seq")
_V2_KEYS = ("vocab_size", "d_model", "n_layers", "n_heads", "n_kv_heads", "d_ff", "max_seq")


def write_tlm(path: pathlib.Path, cfg: dict, params: dict) -> None:
    tensors = {}
    for name, arr in params.items():
        a = np.asarray(arr, dtype=np.float32)
        if a.ndim == 1:
            a = a.reshape(1, -1)
        assert a.ndim == 2, f"{name}: rank {a.ndim}"
        tensors[name] = a

    n_kv = cfg.get("n_kv_heads", cfg["n_heads"])
    gqa = n_kv != cfg["n_heads"]
    with open(path, "wb") as f:
        f.write(MAGIC_V2 if gqa else MAGIC)
        for key in _V2_KEYS if gqa else _V1_KEYS:
            f.write(struct.pack("<I", cfg[key] if key != "n_kv_heads" else n_kv))
        f.write(struct.pack("<I", len(tensors)))
        for name in sorted(tensors):  # BTreeMap order on the rust side
            a = tensors[name]
            nb = name.encode()
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<II", a.shape[0], a.shape[1]))
            f.write(a.astype("<f4").tobytes())


def read_tlm(path: pathlib.Path):
    """Reader (round-trip tests + loading checkpoints back for AOT)."""
    with open(path, "rb") as f:
        magic = f.read(4)
        assert magic in (MAGIC, MAGIC_V2), "bad magic"
        keys = _V2_KEYS if magic == MAGIC_V2 else _V1_KEYS
        cfg = {k: struct.unpack("<I", f.read(4))[0] for k in keys}
        # Legacy TLM1 headers predate GQA: every head is a KV head.
        cfg.setdefault("n_kv_heads", cfg["n_heads"])
        (n,) = struct.unpack("<I", f.read(4))
        params = {}
        for _ in range(n):
            (ln,) = struct.unpack("<I", f.read(4))
            name = f.read(ln).decode()
            rows, cols = struct.unpack("<II", f.read(8))
            data = np.frombuffer(f.read(rows * cols * 4), dtype="<f4").reshape(rows, cols)
            params[name] = data.copy()
        # squeeze the vectors back
        for k in list(params):
            if params[k].shape[0] == 1 and ("norm" in k):
                params[k] = params[k][0]
    return cfg, params
