"""CI perf gate: fail on decode tokens/sec regressions.

Compares the freshly-benched ``BENCH_decode.json`` against the previous
uploaded artifact (same schema: ``{"bench": ..., "rows": [...]}`` with a
``name`` and ``tokens_per_sec`` per row) and exits non-zero when any
matched row regresses by more than ``--threshold`` (default 15%).

Rows are matched by ``name``; rows present on only one side are
reported but never fail the gate (configs come and go). Rows whose
previous tokens/sec is 0 (degenerate zero-wall-clock runs) are skipped
— a ratio against zero means nothing.

Stdlib only; runs on the bare CI python.
"""

from __future__ import annotations

import argparse
import json
import sys


def load_rows(path: str) -> dict[str, float]:
    with open(path) as f:
        doc = json.load(f)
    out: dict[str, float] = {}
    for row in doc.get("rows", []):
        name = row.get("name")
        tps = row.get("tokens_per_sec")
        if isinstance(name, str) and isinstance(tps, (int, float)):
            out[name] = float(tps)
    return out


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("current", help="fresh BENCH_decode.json")
    ap.add_argument("previous", help="previous run's BENCH_decode.json")
    ap.add_argument("--threshold", type=float, default=0.15,
                    help="max allowed fractional tokens/sec drop (0.15 = 15%%)")
    args = ap.parse_args()

    cur = load_rows(args.current)
    prev = load_rows(args.previous)
    if not prev:
        print("[perf-gate] previous artifact has no comparable rows — skipping")
        return 0

    failures = []
    for name in sorted(prev):
        if name not in cur:
            print(f"[perf-gate] row dropped (not gating): {name}")
            continue
        p, c = prev[name], cur[name]
        if p <= 0.0:
            print(f"[perf-gate] skipping zero-baseline row: {name}")
            continue
        ratio = c / p
        marker = "OK "
        if ratio < 1.0 - args.threshold:
            marker = "REG"
            failures.append((name, p, c, ratio))
        print(f"[perf-gate] {marker} {name}: {p:.1f} -> {c:.1f} tok/s "
              f"({100.0 * (ratio - 1.0):+.1f}%)")
    for name in sorted(set(cur) - set(prev)):
        print(f"[perf-gate] new row (not gated): {name}")

    if failures:
        print(f"\n[perf-gate] FAIL: {len(failures)} row(s) regressed more than "
              f"{100.0 * args.threshold:.0f}%:")
        for name, p, c, ratio in failures:
            print(f"  {name}: {p:.1f} -> {c:.1f} tok/s ({100.0 * (ratio - 1.0):+.1f}%)")
        return 1
    print("\n[perf-gate] PASS")
    return 0


if __name__ == "__main__":
    sys.exit(main())
