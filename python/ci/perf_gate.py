"""CI perf gate: fail on decode throughput or TTFT regressions.

Compares the freshly-benched ``BENCH_decode.json`` against the previous
uploaded artifact (same schema: ``{"bench": ..., "rows": [...]}`` with a
``name``, ``tokens_per_sec``, and — since the streaming scheduler —
``ttft_p95_us`` per row) and exits non-zero when any matched row:

* drops tokens/sec by more than ``--threshold`` (default 15%), or
* grows TTFT p95 by more than ``--ttft-threshold`` (default 25% —
  looser, because tail first-token latency on tiny CI models is noisier
  than steady-state throughput).

Rows are matched by ``name`` **plus** the KV-cache format: since the
quantized-KV serving path, rows carry a ``kv_bits`` field (0 = f32 KV,
2..4 = bit-plane KV) and the match key is ``name [kvN]`` — a
quantized-KV row can only gate against a quantized-KV baseline, so
regressions in the f32 rows are never masked by (or blamed on) the
packed-KV rows sharing a name, and vice versa. Rows present on only
one side are reported but never fail the gate (configs come and go).
Rows whose previous value is 0 (degenerate zero-wall-clock runs, or
artifacts predating the TTFT field) are skipped — a ratio against zero
means nothing.

A **missing or unreadable previous artifact** is a loud skip, not an
error: the very first run on a branch (or a wiped artifact store) has
no baseline, and failing the gate there would block every bootstrap.
The *current* file must always load — the bench just ran.

Since the prefix cache, the Zipf section of the bench emits paired
``… cold`` / ``… warm`` rows (same prompts, cache off vs on). Besides
gating each against its own baseline like any other row, the gate
compares them **within the current artifact**: a warm (cache-hit) row's
TTFT p50 must stay below its cold twin's within ``--hit-ttft-margin``
(default 25% headroom) — a cache hit that doesn't beat cold prefill
means the borrow path regressed, and no historical baseline is needed
to see it.

Since chunked prefill, the mixed long/short bench section emits paired
``… chunked`` / ``… unchunked`` rows the same way; the gate requires the
chunked run's **short-request** TTFT p95 to stay within
``--chunked-ttft-margin`` of the unchunked run's — short requests must
not stall behind long prefills once chunking is on.

Since the SIMD dispatch layer, the gate also (optionally) compares the
per-kernel-family bench ``BENCH_kernels.json`` via ``--kernels-current``
/ ``--kernels-previous``. Kernel rows are keyed by
``(family, kv_bits, tier)`` and gate on ``us_per_iter`` — lower is
better, so the gate fires when time *grows* by more than
``--kernels-threshold`` (default 15%). A missing or unreadable previous
kernels file is skipped gracefully (the artifact predates the bench);
a missing *current* file while ``--kernels-current`` was passed is an
error — the bench was supposed to run.

Since the HTTP/SSE front door, the gate also (optionally) compares
wire-level loadgen artifacts (``BENCH_serve_load.json`` and friends)
via repeatable ``--serve-load-current`` / ``--serve-load-previous``
pairs, matched by position. Serve-load rows are keyed like decode rows
(``name [kvN]``) and gate on two axes: ``goodput_tok_s`` drops like
tokens/sec (more than ``--threshold`` fails), and ``rejection_rate``
gates on **absolute** growth — more than ``--rejection-margin`` above
the previous rate fails — because ratios against a near-zero rejection
rate are meaningless. A missing previous serve-load file is a loud
skip, same as every other baseline here.

Stdlib only; runs on the bare CI python.
"""

from __future__ import annotations

import argparse
import json
import sys


def load_rows(path: str) -> dict[str, dict[str, float]]:
    with open(path) as f:
        doc = json.load(f)
    out: dict[str, dict[str, float]] = {}
    for row in doc.get("rows", []):
        name = row.get("name")
        if not isinstance(name, str):
            continue
        # Key on (name, kv format) so f32 and quantized-KV rows gate
        # against their own baselines only. Artifacts predating kv_bits
        # behave as kv_bits == 0 (every row was f32 KV back then).
        kv_bits = row.get("kv_bits")
        if isinstance(kv_bits, (int, float)) and int(kv_bits) != 0:
            name = f"{name} [kv{int(kv_bits)}]"
        vals: dict[str, float] = {}
        for key in ("tokens_per_sec", "ttft_p95_us", "ttft_p50_us",
                    "short_ttft_p95_us"):
            v = row.get(key)
            if isinstance(v, (int, float)):
                vals[key] = float(v)
        if vals:
            out[name] = vals
    return out


def load_kernel_rows(path: str) -> dict[str, float]:
    """``BENCH_kernels.json`` rows keyed ``family [kvN] @tier`` ->
    ``us_per_iter``. Rows without the full key or a positive time are
    dropped (they cannot be gated meaningfully)."""
    with open(path) as f:
        doc = json.load(f)
    out: dict[str, float] = {}
    for row in doc.get("rows", []):
        family = row.get("family")
        tier = row.get("tier")
        us = row.get("us_per_iter")
        if not (isinstance(family, str) and isinstance(tier, str)):
            continue
        if not isinstance(us, (int, float)) or us <= 0.0:
            continue
        kv_bits = row.get("kv_bits")
        kv = int(kv_bits) if isinstance(kv_bits, (int, float)) else 0
        out[f"{family} [kv{kv}] @{tier}"] = float(us)
    return out


def gate_kernels(current: str, previous: str, threshold: float,
                 failures: list) -> None:
    """Compare kernel-family rows; append regressions to ``failures``.

    The previous artifact may simply not contain the kernels file yet
    (bench landed after the last main run) — that skips. The *current*
    file must exist: the caller only passes ``--kernels-current`` when
    the bench ran in this job.
    """
    cur = load_kernel_rows(current)
    try:
        prev = load_kernel_rows(previous)
    except (OSError, json.JSONDecodeError) as e:
        print(f"[perf-gate] no previous kernels baseline ({e}) — skipping")
        return
    if not prev:
        print("[perf-gate] previous kernels artifact has no comparable rows — skipping")
        return
    for name in sorted(prev):
        if name not in cur:
            print(f"[perf-gate] kernel row dropped (not gating): {name}")
            continue
        p, c = prev[name], cur[name]
        ratio = c / p
        marker = "OK "
        if ratio > 1.0 + threshold:
            marker = "REG"
            failures.append((name, "us_per_iter", p, c, ratio))
        print(f"[perf-gate] {marker} {name}: {p:.2f} -> {c:.2f} us/iter "
              f"({100.0 * (ratio - 1.0):+.1f}%)")
    for name in sorted(set(cur) - set(prev)):
        print(f"[perf-gate] new kernel row (not gated): {name}")


def load_serve_rows(path: str) -> dict[str, dict[str, float]]:
    """Loadgen artifact rows keyed ``name [kvN]`` -> goodput/rejection."""
    with open(path) as f:
        doc = json.load(f)
    out: dict[str, dict[str, float]] = {}
    for row in doc.get("rows", []):
        name = row.get("name")
        if not isinstance(name, str):
            continue
        kv_bits = row.get("kv_bits")
        if isinstance(kv_bits, (int, float)) and int(kv_bits) != 0:
            name = f"{name} [kv{int(kv_bits)}]"
        vals: dict[str, float] = {}
        for key in ("goodput_tok_s", "rejection_rate"):
            v = row.get(key)
            if isinstance(v, (int, float)):
                vals[key] = float(v)
        if vals:
            out[name] = vals
    return out


def gate_serve_load(current: str, previous: str, threshold: float,
                    rejection_margin: float, failures: list) -> None:
    """Compare one pair of wire-level loadgen artifacts.

    Goodput gates like tokens/sec (fractional drop beyond ``threshold``
    fails); rejection rate gates on absolute growth beyond
    ``rejection_margin``, because a baseline rate of (near) zero makes
    any ratio meaningless. A missing or unreadable previous file is a
    loud skip — the first run after the loadgen landed has no baseline.
    The current file must load: the caller only passes it when the
    loadgen ran in this job.
    """
    cur = load_serve_rows(current)
    try:
        prev = load_serve_rows(previous)
    except (OSError, json.JSONDecodeError) as e:
        print(f"[perf-gate] no previous serve-load baseline ({e}) — skipping")
        return
    if not prev:
        print("[perf-gate] previous serve-load artifact has no comparable "
              "rows — skipping")
        return
    for name in sorted(prev):
        if name not in cur:
            print(f"[perf-gate] serve-load row dropped (not gating): {name}")
            continue
        p_good = prev[name].get("goodput_tok_s", 0.0)
        c_good = cur[name].get("goodput_tok_s", 0.0)
        if p_good <= 0.0:
            print(f"[perf-gate] skipping zero-baseline goodput row: {name}")
        else:
            ratio = c_good / p_good
            marker = "OK "
            if ratio < 1.0 - threshold:
                marker = "REG"
                failures.append((name, "goodput_tok_s", p_good, c_good, ratio))
            print(f"[perf-gate] {marker} {name}: {p_good:.1f} -> {c_good:.1f} "
                  f"goodput tok/s ({100.0 * (ratio - 1.0):+.1f}%)")
        p_rr = prev[name].get("rejection_rate")
        c_rr = cur[name].get("rejection_rate")
        if p_rr is None or c_rr is None:
            print(f"[perf-gate] skipping rejection-rate row (no data): {name}")
            continue
        marker = "OK "
        if c_rr > p_rr + rejection_margin:
            marker = "REG"
            failures.append((name, "rejection_rate", p_rr, c_rr,
                             (1.0 + c_rr) / (1.0 + p_rr)))
        print(f"[perf-gate] {marker} {name}: rejection rate {p_rr:.2f} -> "
              f"{c_rr:.2f} (+{rejection_margin:.2f} allowed)")
    for name in sorted(set(cur) - set(prev)):
        print(f"[perf-gate] new serve-load row (not gated): {name}")


def gate_cache_hit(cur: dict[str, dict[str, float]], margin: float,
                   failures: list) -> None:
    """Within-artifact hit-vs-cold TTFT check for the Zipf rows.

    Pairs every ``… warm`` row with its ``… cold`` twin (the ``[kvN]``
    suffix rides along, so packed-KV pairs match packed-KV pairs) and
    fails when the warm TTFT p50 exceeds cold × (1 + margin). Needs no
    previous artifact — both rows come from the same bench run.
    """
    for name in sorted(cur):
        if " warm" not in name:
            continue
        cold_name = name.replace(" warm", " cold")
        cold = cur.get(cold_name)
        if cold is None:
            print(f"[perf-gate] warm row has no cold twin (not gating): {name}")
            continue
        c_warm = cur[name].get("ttft_p50_us", 0.0)
        c_cold = cold.get("ttft_p50_us", 0.0)
        if c_warm <= 0.0 or c_cold <= 0.0:
            print(f"[perf-gate] skipping hit-TTFT pair (no p50 data): {name}")
            continue
        ratio = c_warm / c_cold
        marker = "OK "
        if ratio > 1.0 + margin:
            marker = "REG"
            failures.append((name, "hit_vs_cold_ttft_p50", c_cold, c_warm, ratio))
        print(f"[perf-gate] {marker} {name}: cache-hit TTFT p50 {c_warm:.0f} us "
              f"vs cold {c_cold:.0f} us ({100.0 * (ratio - 1.0):+.1f}%)")


def gate_chunked_prefill(cur: dict[str, dict[str, float]], margin: float,
                         failures: list) -> None:
    """Within-artifact chunked-vs-unchunked short-TTFT check.

    Pairs every ``… chunked`` row with its ``… unchunked`` twin from the
    mixed long/short bench section and fails when the chunked run's
    short-request TTFT p95 exceeds unchunked × (1 + margin) — chunked
    prefill exists so short requests stay stall-free while long prompts
    prefill; losing that (or merely matching the stall) is a regression
    in the thing the feature ships. Needs no previous artifact — both
    rows come from the same bench run.
    """
    for name in sorted(cur):
        if " chunked" not in name or " unchunked" in name:
            continue
        twin_name = name.replace(" chunked", " unchunked")
        twin = cur.get(twin_name)
        if twin is None:
            print(f"[perf-gate] chunked row has no unchunked twin "
                  f"(not gating): {name}")
            continue
        c_chunk = cur[name].get("short_ttft_p95_us", 0.0)
        c_plain = twin.get("short_ttft_p95_us", 0.0)
        if c_chunk <= 0.0 or c_plain <= 0.0:
            print(f"[perf-gate] skipping chunked-TTFT pair (no p95 data): {name}")
            continue
        ratio = c_chunk / c_plain
        marker = "OK "
        if ratio > 1.0 + margin:
            marker = "REG"
            failures.append((name, "chunked_vs_unchunked_short_ttft_p95",
                             c_plain, c_chunk, ratio))
        print(f"[perf-gate] {marker} {name}: chunked short TTFT p95 "
              f"{c_chunk:.0f} us vs unchunked {c_plain:.0f} us "
              f"({100.0 * (ratio - 1.0):+.1f}%)")


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("current", help="fresh BENCH_decode.json")
    ap.add_argument("previous", help="previous run's BENCH_decode.json")
    ap.add_argument("--threshold", type=float, default=0.15,
                    help="max allowed fractional tokens/sec drop (0.15 = 15%%)")
    ap.add_argument("--ttft-threshold", type=float, default=0.25,
                    help="max allowed fractional TTFT p95 growth (0.25 = 25%%)")
    ap.add_argument("--kernels-current", default=None,
                    help="fresh BENCH_kernels.json (optional)")
    ap.add_argument("--kernels-previous", default=None,
                    help="previous run's BENCH_kernels.json (optional)")
    ap.add_argument("--kernels-threshold", type=float, default=0.15,
                    help="max allowed fractional us/iter growth per kernel "
                         "family (0.15 = 15%%)")
    ap.add_argument("--hit-ttft-margin", type=float, default=0.25,
                    help="headroom for the within-run cache-hit TTFT check: "
                         "warm p50 may exceed cold p50 by this fraction "
                         "(0.25 = 25%%)")
    ap.add_argument("--chunked-ttft-margin", type=float, default=0.25,
                    help="headroom for the within-run chunked-prefill check: "
                         "the chunked run's short-request TTFT p95 may exceed "
                         "the unchunked run's by this fraction (0.25 = 25%%)")
    ap.add_argument("--serve-load-current", action="append", default=[],
                    help="fresh BENCH_serve_*.json (repeatable; paired by "
                         "position with --serve-load-previous)")
    ap.add_argument("--serve-load-previous", action="append", default=[],
                    help="previous run's BENCH_serve_*.json (repeatable)")
    ap.add_argument("--rejection-margin", type=float, default=0.15,
                    help="max allowed absolute rejection-rate growth for "
                         "serve-load rows (0.15 = 15 points)")
    args = ap.parse_args()

    cur = load_rows(args.current)
    try:
        prev = load_rows(args.previous)
    except (OSError, json.JSONDecodeError) as e:
        # First run on a branch / wiped artifact store: no baseline to
        # gate against. Skip loudly rather than erroring — the
        # within-run checks below still apply.
        print(f"[perf-gate] no previous decode baseline ({e}) — skipping decode gate")
        prev = {}
    failures = []
    gate_cache_hit(cur, args.hit_ttft_margin, failures)
    gate_chunked_prefill(cur, args.chunked_ttft_margin, failures)
    if args.kernels_current and args.kernels_previous:
        gate_kernels(args.kernels_current, args.kernels_previous,
                     args.kernels_threshold, failures)
    if len(args.serve_load_current) != len(args.serve_load_previous):
        print("[perf-gate] serve-load current/previous counts differ — "
              "pairing by position, extras skipped")
    for sl_cur, sl_prev in zip(args.serve_load_current,
                               args.serve_load_previous):
        gate_serve_load(sl_cur, sl_prev, args.threshold,
                        args.rejection_margin, failures)
    if not prev:
        print("[perf-gate] previous artifact has no comparable rows — skipping decode gate")
        if failures:
            print(f"\n[perf-gate] FAIL: {len(failures)} regression(s):")
            for name, metric, p, c, ratio in failures:
                print(f"  {name} [{metric}]: {p:.1f} -> {c:.1f} "
                      f"({100.0 * (ratio - 1.0):+.1f}%)")
            return 1
        return 0
    for name in sorted(prev):
        if name not in cur:
            print(f"[perf-gate] row dropped (not gating): {name}")
            continue

        p_tps = prev[name].get("tokens_per_sec", 0.0)
        c_tps = cur[name].get("tokens_per_sec", 0.0)
        if p_tps <= 0.0:
            print(f"[perf-gate] skipping zero-baseline tok/s row: {name}")
        else:
            ratio = c_tps / p_tps
            marker = "OK "
            if ratio < 1.0 - args.threshold:
                marker = "REG"
                failures.append((name, "tokens/sec", p_tps, c_tps, ratio))
            print(f"[perf-gate] {marker} {name}: {p_tps:.1f} -> {c_tps:.1f} tok/s "
                  f"({100.0 * (ratio - 1.0):+.1f}%)")

        # TTFT p95: lower is better, so the gate fires on *growth*.
        # Rows from artifacts predating the streaming scheduler have no
        # ttft_p95_us — skipped until a baseline exists.
        p_ttft = prev[name].get("ttft_p95_us", 0.0)
        c_ttft = cur[name].get("ttft_p95_us", 0.0)
        if p_ttft <= 0.0 or c_ttft <= 0.0:
            print(f"[perf-gate] skipping TTFT row (no baseline): {name}")
        else:
            ratio = c_ttft / p_ttft
            marker = "OK "
            if ratio > 1.0 + args.ttft_threshold:
                marker = "REG"
                failures.append((name, "ttft_p95", p_ttft, c_ttft, ratio))
            print(f"[perf-gate] {marker} {name}: {p_ttft:.0f} -> {c_ttft:.0f} us TTFT p95 "
                  f"({100.0 * (ratio - 1.0):+.1f}%)")

    for name in sorted(set(cur) - set(prev)):
        print(f"[perf-gate] new row (not gated): {name}")

    if failures:
        print(f"\n[perf-gate] FAIL: {len(failures)} regression(s):")
        for name, metric, p, c, ratio in failures:
            print(f"  {name} [{metric}]: {p:.1f} -> {c:.1f} "
                  f"({100.0 * (ratio - 1.0):+.1f}%)")
        return 1
    print("\n[perf-gate] PASS")
    return 0


if __name__ == "__main__":
    sys.exit(main())
