"""L2 model tests: shapes, causality, decode-step parity, loss sanity."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model


CFG = model.config(vocab_size=20, d_model=16, n_layers=2, n_heads=2,
                   d_ff=24, max_seq=32)


def params():
    return model.init_params(CFG, jax.random.PRNGKey(0))


def test_forward_shapes():
    p = params()
    logits = model.forward(p, CFG, jnp.arange(5, dtype=jnp.int32))
    assert logits.shape == (5, 20)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_causality():
    p = params()
    a = model.forward(p, CFG, jnp.array([1, 2, 3, 4], jnp.int32))
    b = model.forward(p, CFG, jnp.array([1, 2, 3, 15], jnp.int32))
    np.testing.assert_allclose(np.asarray(a[:3]), np.asarray(b[:3]),
                               rtol=1e-5, atol=1e-5)
    assert not np.allclose(np.asarray(a[3]), np.asarray(b[3]))


def test_decode_step_matches_full_forward():
    """The functional KV-cache step must agree with the batch forward —
    the exact property the rust DecodeState test asserts, so all three
    implementations (jax full, jax step, rust) agree pairwise."""
    p = params()
    toks = jnp.array([3, 7, 1, 12, 5], jnp.int32)
    full = np.asarray(model.forward(p, CFG, toks))
    cache_len = 8
    k = jnp.zeros((CFG["n_layers"], cache_len, CFG["d_model"]), jnp.float32)
    v = jnp.zeros_like(k)
    for t in range(len(toks)):
        logits, k, v = model.decode_step(p, CFG, toks[t], jnp.int32(t), k, v)
        np.testing.assert_allclose(np.asarray(logits), full[t],
                                   rtol=1e-4, atol=1e-4)


def test_loss_decreases_on_repeated_batch():
    """Two gradient steps on one batch must reduce that batch's loss."""
    p = params()
    toks = jnp.asarray(np.random.default_rng(0).integers(0, 20, (4, 16)),
                       jnp.int32)
    mask = jnp.ones_like(toks, jnp.float32)
    loss0 = model.loss_fn(p, CFG, toks, mask)
    g = jax.grad(model.loss_fn)(p, CFG, toks, mask)
    p2 = jax.tree.map(lambda w, gw: w - 0.1 * gw, p, g)
    loss1 = model.loss_fn(p2, CFG, toks, mask)
    assert float(loss1) < float(loss0)


def test_rope_identity_at_pos0():
    cos, sin = model.rope_tables(1, 8)
    x = jnp.ones((1, 2, 8))
    y = model.rope_apply(x, cos, sin)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), atol=1e-6)


def test_rmsnorm_matches_definition():
    x = jnp.array([3.0, -4.0])
    g = jnp.ones(2)
    y = np.asarray(model.rmsnorm(x, g))
    rms = np.sqrt(12.5 + model.RMS_EPS)
    np.testing.assert_allclose(y, [3 / rms, -4 / rms], rtol=1e-5)


def test_param_names_match_tlm_contract():
    p = params()
    expected = {"embed", "lm_head", "norm_f"}
    for l in range(CFG["n_layers"]):
        for n in ("norm1", "norm2", "wq", "wk", "wv", "wo", "w1", "w2", "w3"):
            expected.add(f"l{l}.{n}")
    assert set(p.keys()) == expected
