"""Build-path tests: .tlm export round-trip and HLO artifact generation."""

import pathlib
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model
from compile.export_weights import read_tlm, write_tlm


CFG = model.config(vocab_size=20, d_model=16, n_layers=1, n_heads=2,
                   d_ff=24, max_seq=32)


def test_tlm_roundtrip():
    p = model.init_params(CFG, jax.random.PRNGKey(1))
    with tempfile.TemporaryDirectory() as d:
        path = pathlib.Path(d) / "m.tlm"
        write_tlm(path, CFG, p)
        cfg2, p2 = read_tlm(path)
        assert cfg2["d_model"] == 16 and cfg2["n_layers"] == 1
        np.testing.assert_allclose(np.asarray(p["embed"]), p2["embed"])
        np.testing.assert_allclose(np.asarray(p["l0.wq"]), p2["l0.wq"])
        # norms come back as vectors
        assert p2["norm_f"].shape == (16,)


def test_hlo_text_parses_as_hlo():
    """Lower a trivial jitted fn and sanity-check the HLO text shape —
    ENTRY, parameters, and a root tuple (return_tuple=True)."""
    lowered = jax.jit(lambda x: (x @ x.T + 1.0,)).lower(
        jax.ShapeDtypeStruct((4, 4), jnp.float32))
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    assert "parameter(0)" in text
    assert "tuple(" in text


def test_lower_kernels_writes_artifacts():
    with tempfile.TemporaryDirectory() as d:
        out = pathlib.Path(d)
        aot.lower_kernels(out, d_in=32, d_out=8, k=2, g=16)
        for name in ("bpdq_gemv.hlo.txt", "dequant_gemv.hlo.txt"):
            path = out / name
            assert path.exists()
            text = path.read_text()
            assert "ENTRY" in text and len(text) > 500


def test_lower_decode_step_small():
    """decode_step lowers with weights baked in and fixed cache shape."""
    p = model.init_params(CFG, jax.random.PRNGKey(2))
    with tempfile.TemporaryDirectory() as d:
        out = pathlib.Path(d)
        ckpt = out / "m.tlm"
        write_tlm(ckpt, CFG, p)
        aot.lower_decode_step(out, ckpt, cache_len=8)
        text = (out / "decode_step.hlo.txt").read_text()
        assert "ENTRY" in text
        meta = (out / "decode_step.meta").read_text()
        assert "cache_len 8" in meta
