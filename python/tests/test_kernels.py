"""L1 correctness: Pallas kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes / plane counts / group sizes; the kernel must
match ref.py to float32 tolerance everywhere. This is THE correctness
signal for the serving hot path — the rust LUT engine implements the
same packed format and is cross-checked against the same oracle via the
AOT round-trip (rust integration tests).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import bpdq_lut, dequant, ref


def make_case(seed, k, d_out, d_in, g):
    rng = np.random.default_rng(seed)
    planes = rng.integers(0, 2, size=(k, d_out, d_in)).astype(np.float32)
    pb = ref.pack_planes(jnp.asarray(planes))
    coeffs = jnp.asarray(rng.normal(size=(k + 1, d_out, d_in // g)).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(d_in,)).astype(np.float32))
    return planes, pb, coeffs, x


# group_size must divide d_in and be a multiple of 8
CASE = st.tuples(
    st.integers(0, 10_000),              # seed
    st.integers(1, 4),                   # k
    st.sampled_from([1, 3, 8, 12, 64]),  # d_out
    st.sampled_from([16, 64, 128]),      # d_in
    st.sampled_from([8, 16, 64]),        # g
).filter(lambda c: c[3] % c[4] == 0)


@settings(max_examples=40, deadline=None)
@given(CASE)
def test_lut_gemv_matches_ref(case):
    seed, k, d_out, d_in, g = case
    _, pb, coeffs, x = make_case(seed, k, d_out, d_in, g)
    want = np.asarray(ref.lut_gemv_ref(x, pb, coeffs, g))
    got = np.asarray(bpdq_lut.lut_gemv(x, pb, coeffs, g))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(CASE)
def test_dequant_gemv_matches_ref(case):
    seed, k, d_out, d_in, g = case
    _, pb, coeffs, x = make_case(seed, k, d_out, d_in, g)
    want = np.asarray(ref.lut_gemv_ref(x, pb, coeffs, g))
    got = np.asarray(dequant.dequant_gemv(x, pb, coeffs, g))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 5),
       st.sampled_from([2, 7, 16]), st.sampled_from([8, 32, 104]))
def test_pack_unpack_roundtrip(seed, k, d_out, d_in):
    rng = np.random.default_rng(seed)
    planes = rng.integers(0, 2, size=(k, d_out, d_in)).astype(np.float32)
    pb = ref.pack_planes(jnp.asarray(planes))
    back = np.asarray(ref.unpack_planes(pb, d_in))
    np.testing.assert_array_equal(back, planes)


def test_dequant_ref_formula():
    """Hand-checked Eq. 1 instance (mirrors the rust packing test)."""
    b1 = np.array([[[1, 0, 1, 1, 0, 0, 0, 0]]], dtype=np.float32)
    b2 = np.array([[[0, 1, 1, 0, 0, 0, 0, 0]]], dtype=np.float32)
    planes = np.concatenate([b1, b2], axis=0)
    pb = ref.pack_planes(jnp.asarray(planes))
    coeffs = jnp.asarray(np.array([
        [[0.5]], [[2.0]], [[10.0]],
    ], dtype=np.float32))  # c0, c1, c2 for the single group of 8
    w = np.asarray(ref.dequant_ref(pb, coeffs, 8, 8))
    np.testing.assert_allclose(
        w[0], [2.5, 10.5, 12.5, 2.5, 0.5, 0.5, 0.5, 0.5], rtol=1e-6)


def test_uniform_grid_is_special_case():
    """Proposition 1 (Eq. 13): c1=s, c2=2s reproduces UINT2 exactly."""
    s = 0.37
    # column j encodes value j∈{0,1,2,3}: b1 = LSB, b2 = MSB
    b1 = np.array([[[0, 1, 0, 1, 0, 0, 0, 0]]], dtype=np.float32)
    b2 = np.array([[[0, 0, 1, 1, 0, 0, 0, 0]]], dtype=np.float32)
    pb = ref.pack_planes(jnp.asarray(np.concatenate([b1, b2], 0)))
    coeffs = jnp.asarray(np.array([[[0.0]], [[s]], [[2 * s]]], np.float32))
    w = np.asarray(ref.dequant_ref(pb, coeffs, 8, 8))
    np.testing.assert_allclose(w[0, :4], [0.0, s, 2 * s, 3 * s], rtol=1e-6)


def test_group_size_validation():
    _, pb, coeffs, x = make_case(0, 2, 8, 64, 16)
    with pytest.raises(AssertionError):
        bpdq_lut.lut_gemv(x, pb, coeffs, 12)  # not a multiple of 8


def test_kernel_zero_x():
    _, pb, coeffs, _ = make_case(1, 2, 8, 64, 16)
    x = jnp.zeros((64,), jnp.float32)
    got = np.asarray(bpdq_lut.lut_gemv(x, pb, coeffs, 16))
    np.testing.assert_allclose(got, np.zeros(8), atol=1e-7)
