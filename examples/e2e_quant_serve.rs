//! End-to-end driver (the EXPERIMENTS.md validation run):
//!
//! load the **trained** tiny-LM checkpoint → quantize it with BPDQ
//! W2-G256 (the paper's extreme deployment point, §4.2) → serve batched
//! few-shot arithmetic requests through the router/batcher on the LUT
//! bit-plane engine → report accuracy, model size, and latency, next to
//! the fp16 baseline served the same way.
//!
//! Run after `make artifacts`:
//! `cargo run --release --example e2e_quant_serve`

use bpdq::data::{tasks, CorpusConfig, CorpusGen, Split, Tokenizer};
use bpdq::eval::{perplexity, run_battery, EvalConfig};
use bpdq::io::tlm::TlmFile;
use bpdq::model::pipeline::quantize_model;
use bpdq::model::Model;
use bpdq::quant::{BpdqConfig, QuantMethod};
use bpdq::serving::{EngineKind, KvFormat, LutModel, Router, RouterConfig, Strategy};
use std::collections::HashMap;
use std::path::Path;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let ckpt = Path::new("artifacts/tiny_small.tlm");
    anyhow::ensure!(ckpt.exists(), "run `make artifacts` first (trains the tiny LM)");
    let model = Arc::new(Model::from_tlm(&TlmFile::load(ckpt)?)?);
    let gen = CorpusGen::new(CorpusConfig::default());
    let tok = Tokenizer::new();
    println!("loaded trained checkpoint: {:.2}M params", model.n_params() as f64 / 1e6);

    // ---- fp16 baseline numbers ----
    let eval_docs = gen.token_docs(Split::Eval, 32, &tok);
    let fp_ppl = perplexity(&model, &eval_docs);
    println!(
        "fp16 baseline: ppl {:.3}, size {:.2} MiB",
        fp_ppl,
        model.fp16_bytes() as f64 / (1 << 20) as f64
    );

    // ---- quantize: BPDQ W2-G256 ----
    let method = QuantMethod::Bpdq(BpdqConfig { k: 2, group_size: 256, ..Default::default() });
    let calib: Vec<Vec<u32>> = gen
        .token_docs(Split::Calib, 64, &tok)
        .into_iter()
        .map(|mut d| {
            d.truncate(model.cfg.max_seq);
            d
        })
        .filter(|d| d.len() >= 8)
        .collect();
    println!("\nquantizing with {} on {} calib seqs…", method.name(), calib.len());
    let qm = quantize_model(&model, &calib, &method)?;
    println!(
        "quantized in {:.1}s: BPW {:.3}, packed size {:.2} MiB ({:.1}% of fp16)",
        qm.quant_secs,
        qm.bits_per_weight(),
        qm.size_bytes() as f64 / (1 << 20) as f64,
        100.0 * qm.size_bytes() as f64 / model.fp16_bytes() as f64
    );
    let q_ppl = perplexity(&qm.model, &eval_docs);
    println!("quantized ppl {:.3} (fp16 {:.3})", q_ppl, fp_ppl);
    let scores = run_battery(
        &qm.model,
        &gen,
        &tok,
        &EvalConfig { n_ppl_docs: 16, n_arith: 32, n_choice: 32, ..Default::default() },
    );
    println!(
        "quantized battery: arith {:.1}%, fact {:.1}%, bool {:.1}%, classify {:.1}%",
        scores.arith * 100.0,
        scores.fact_choice * 100.0,
        scores.bool_fact * 100.0,
        scores.classify * 100.0
    );

    // ---- serve both through the router ----
    let packed: HashMap<_, _> = qm
        .packed
        .iter()
        .map(|(k, v)| (k.clone(), v.as_bit_planes().unwrap().clone()))
        .collect();
    let qmodel = Arc::new(qm.model.clone());
    let trace = tasks::gen_arith(0xE2E, 24, 2);

    // Third serve config: same W2 weights, but the KV cache itself is
    // stored as packed W2 bit-planes (fused-dequant attention) — the
    // full BPDQ deployment point: quantized weights AND quantized KV.
    let kvq_model = Arc::new(qmodel.with_kv_format(KvFormat::bit_plane(2)));
    println!(
        "\nKV cache: f32 {:.2} MiB/session vs W2 bit-plane {:.2} MiB/session ({:.1}x smaller)",
        qmodel.kv_bytes_per_session() as f64 / (1 << 20) as f64,
        kvq_model.kv_bytes_per_session() as f64 / (1 << 20) as f64,
        qmodel.kv_bytes_per_session() as f64 / kvq_model.kv_bytes_per_session() as f64
    );
    for (label, kind) in [
        ("fp16 / native engine", EngineKind::Native(model.clone())),
        ("BPDQ-W2-G256 / LUT engine", EngineKind::Lut(LutModel::new(qmodel.clone(), packed.clone())?)),
        (
            "BPDQ-W2 + KV-W2 / LUT engine",
            EngineKind::Lut(LutModel::new(kvq_model.clone(), packed.clone())?),
        ),
    ] {
        let router = Router::start(
            RouterConfig { n_workers: 2, max_batch: 6, strategy: Strategy::LeastLoaded },
            |_| Ok(kind.clone()),
        )?;
        let streams: Vec<_> = trace
            .iter()
            .map(|t| router.submit(tok.encode(&t.prompt), 8))
            .collect();
        let mut correct = 0;
        for (s, t) in streams.into_iter().zip(&trace) {
            let resp = s.collect()?;
            if tok.decode(&resp.tokens).starts_with(t.answer.as_str()) {
                correct += 1;
            }
        }
        let s = router.metrics.summary();
        println!(
            "\n[{label}] {} reqs, EM {:.1}%, p50 first-token {:.2} ms, decode {:.1} µs/tok, {:.1} tok/s",
            s.completed,
            100.0 * correct as f64 / trace.len() as f64,
            s.p50_first_us as f64 / 1e3,
            s.us_per_token,
            s.tokens_per_sec
        );
        router.shutdown();
    }
    println!("\nE2E OK — all layers composed (data → train(py) → quantize → pack → serve).");
    Ok(())
}
