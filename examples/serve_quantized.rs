//! Serving demo: multi-worker router + dynamic batcher over the LUT
//! bit-plane engine, with a burst-y request trace (interactive chat
//! shape) and a metrics report.
//!
//! Run after `make artifacts`:
//! `cargo run --release --example serve_quantized`
//!
//! This example drives the router in-process. The same stack serves
//! over real sockets via `bpdq serve --listen host:port` — `POST
//! /v1/generate` streams SSE token events (`GET /healthz`, `GET
//! /metrics`, `POST /admin/drain` ride along, plus a length-prefixed
//! raw protocol for dependency-free clients), with admission control
//! under `--deadline-budget-us` and graceful drain. `bpdq loadgen`
//! replays Zipf-distributed wire traffic against it and reports
//! goodput, TTFT/ITL percentiles, rejection rate, and cache hit rate;
//! see the `## Front door` section of `bpdq::serving` for the wire
//! contract.

use bpdq::data::{CorpusConfig, CorpusGen, Split, Tokenizer};
use bpdq::io::tlm::TlmFile;
use bpdq::model::pipeline::quantize_model;
use bpdq::model::{synthetic_model, Model, ModelConfig};
use bpdq::quant::{BpdqConfig, QuantMethod};
use bpdq::serving::{EngineKind, LutModel, Router, RouterConfig, Strategy};
use std::collections::HashMap;
use std::path::Path;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let tok = Tokenizer::new();
    let model = match TlmFile::load(Path::new("artifacts/tiny_small.tlm")) {
        Ok(f) => Model::from_tlm(&f)?,
        Err(_) => {
            eprintln!("(no trained checkpoint — using synthetic weights; run `make artifacts`)");
            synthetic_model(&ModelConfig::tiny_small(tok.vocab_size()), 7)
        }
    };
    let model = Arc::new(model);
    let gen = CorpusGen::new(CorpusConfig::default());

    // Quantize to the serving format.
    let calib: Vec<Vec<u32>> = gen
        .token_docs(Split::Calib, 48, &tok)
        .into_iter()
        .map(|mut d| {
            d.truncate(model.cfg.max_seq);
            d
        })
        .filter(|d| d.len() >= 8)
        .collect();
    let qm = quantize_model(
        &model,
        &calib,
        &QuantMethod::Bpdq(BpdqConfig { k: 2, group_size: 128, ..Default::default() }),
    )?;
    let packed: HashMap<_, _> = qm
        .packed
        .iter()
        .map(|(k, v)| (k.clone(), v.as_bit_planes().unwrap().clone()))
        .collect();
    let qmodel = Arc::new(qm.model.clone());
    println!(
        "serving BPDQ-W2-G128: {:.2} MiB packed ({:.1}% of fp16)",
        qm.size_bytes() as f64 / (1 << 20) as f64,
        100.0 * qm.size_bytes() as f64 / model.fp16_bytes() as f64
    );

    // Compare routing strategies under a bursty trace.
    for strategy in [Strategy::RoundRobin, Strategy::LeastLoaded] {
        let router = Router::start(
            RouterConfig { n_workers: 3, max_batch: 4, strategy },
            |_| Ok(EngineKind::Lut(LutModel::new(qmodel.clone(), packed.clone()).unwrap())),
        )?;
        // Burst: prompts of very different lengths (skewed load).
        let mut streams = Vec::new();
        for i in 0..18u64 {
            let len = if i % 3 == 0 { 60 } else { 8 };
            let prompt: Vec<u32> = (0..len).map(|t| ((t * 5 + i as usize) % 68) as u32).collect();
            streams.push(router.submit(prompt, 6));
        }
        for s in streams {
            s.collect()?;
        }
        let s = router.metrics.summary();
        println!(
            "{:?}: p50 queue {:.2} ms, p50 TTFT {:.2} ms, p95 TTFT {:.2} ms, \
             p50 ITL {:.2} ms, {:.1} tok/s, mean sweep {:.2}",
            strategy,
            s.p50_queue_us as f64 / 1e3,
            s.p50_first_us as f64 / 1e3,
            s.p95_first_us as f64 / 1e3,
            s.p50_itl_us as f64 / 1e3,
            s.tokens_per_sec,
            s.mean_decode_batch
        );
        router.shutdown();
    }
    Ok(())
}
