//! Long-context stress test (paper Fig. 3): passkey retrieval at
//! increasing distance, per quantization method — generalization beyond
//! the training context is exactly where 2-bit damage shows first.
//!
//! Run after `make artifacts`:
//! `cargo run --release --example longcontext_eval`

use bpdq::data::{tasks, CorpusConfig, CorpusGen, Split, Tokenizer};
use bpdq::eval::longctx;
use bpdq::io::tlm::TlmFile;
use bpdq::model::pipeline::quantize_model;
use bpdq::model::Model;
use bpdq::quant::{BpdqConfig, QuantMethod, UniformConfig};
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let ckpt = Path::new("artifacts/tiny_small.tlm");
    anyhow::ensure!(ckpt.exists(), "run `make artifacts` first");
    let model = Model::from_tlm(&TlmFile::load(ckpt)?)?;
    let gen = CorpusGen::new(CorpusConfig::default());
    let tok = Tokenizer::new();
    let n = 24;

    let calib: Vec<Vec<u32>> = gen
        .token_docs(Split::Calib, 48, &tok)
        .into_iter()
        .map(|mut d| {
            d.truncate(model.cfg.max_seq);
            d
        })
        .filter(|d| d.len() >= 8)
        .collect();

    let variants: Vec<(String, Model)> = {
        let mut v = vec![("FP16".to_string(), model.clone())];
        for (name, method) in [
            (
                "GPTQ-W2-G32",
                QuantMethod::Gptq(UniformConfig { bits: 2, group_size: 32, act_order: true }),
            ),
            (
                "BPDQ-W2-G64",
                QuantMethod::Bpdq(BpdqConfig { k: 2, group_size: 64, ..Default::default() }),
            ),
        ] {
            eprintln!("quantizing {name}…");
            v.push((name.to_string(), quantize_model(&model, &calib, &method)?.model));
        }
        v
    };

    println!("\npasskey retrieval accuracy vs distance (filler clauses):");
    print!("{:<14}", "distance");
    for d in [2usize, 4, 8, 16, 24] {
        print!("{d:>8}");
    }
    println!();
    for (name, m) in &variants {
        print!("{name:<14}");
        for d in [2usize, 4, 8, 16, 24] {
            let acc = longctx(m, &tok, &tasks::gen_passkey(&gen, 77, n, d));
            print!("{:>7.1}%", acc * 100.0);
        }
        println!();
    }
    println!("\n(paper Fig. 3 shape: fp16 ≈ BPDQ-W2 degrade gently with distance;");
    println!(" GPTQ-W2 loses retrieval much earlier)");
    Ok(())
}
