//! Bit-width × group-size sweep on one linear layer: reproduces the
//! feasible-set story of Appendix A as numbers — how the variable grid's
//! advantage over the fixed grid grows as bits shrink and groups widen.
//!
//! Run: `cargo run --release --example quantize_sweep`

use bpdq::quant::{quantize_linear, BpdqConfig, QuantMethod, UniformConfig};
use bpdq::rng::Rng;
use bpdq::tensor::Matrix;

fn main() -> anyhow::Result<()> {
    let (d_out, d_in, n) = (96, 256, 192);
    let mut rng = Rng::new(7);
    let w = Matrix::from_vec(
        d_out,
        d_in,
        (0..d_out * d_in).map(|_| 0.1 * rng.student_t(5.0) as f32).collect(),
    );
    let x = Matrix::from_vec(
        n,
        d_in,
        (0..n * d_in)
            .map(|i| ((1.0 / (1.0 + (i % d_in) as f64)).sqrt() * 3.0 + 0.05) as f32 * rng.normal() as f32)
            .collect(),
    );

    println!("output-aligned error ‖(W−Ŵ)X‖²_F (lower is better); ratio = GPTQ/BPDQ\n");
    println!("{:>4} {:>6} | {:>12} {:>12} {:>8}", "bits", "group", "GPTQ", "BPDQ", "ratio");
    for bits in [4u8, 3, 2] {
        for g in [32usize, 64, 128] {
            let e_gptq = quantize_linear(
                &w,
                &x,
                QuantMethod::Gptq(UniformConfig { bits, group_size: g, act_order: true }),
            )?
            .stats
            .output_err;
            let e_bpdq = quantize_linear(
                &w,
                &x,
                QuantMethod::Bpdq(BpdqConfig { k: bits, group_size: g, ..Default::default() }),
            )?
            .stats
            .output_err;
            println!(
                "{bits:>4} {g:>6} | {e_gptq:>12.4} {e_bpdq:>12.4} {:>7.2}×",
                e_gptq / e_bpdq
            );
        }
    }
    println!("\nThe ratio grows as bits drop — the shape-invariance penalty the paper");
    println!("identifies (§1): at 4-bit a fixed grid is fine; at 2-bit it dominates.");

    // Ablation: iterations and GAR (the design choices DESIGN.md calls out).
    println!("\nablation at W2-G64 (output err):");
    for (label, cfg) in [
        ("init only (0 refinement iters)", BpdqConfig { k: 2, group_size: 64, iters: 1, ..Default::default() }),
        ("3 iters", BpdqConfig { k: 2, group_size: 64, iters: 3, ..Default::default() }),
        ("10 iters (paper)", BpdqConfig { k: 2, group_size: 64, iters: 10, ..Default::default() }),
        ("10 iters, GAR off", BpdqConfig { k: 2, group_size: 64, iters: 10, gar: false, ..Default::default() }),
    ] {
        let e = quantize_linear(&w, &x, QuantMethod::Bpdq(cfg))?.stats.output_err;
        println!("  {label:<32} {e:.4}");
    }
    Ok(())
}
