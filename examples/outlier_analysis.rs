//! Activation outlier analysis (paper Table 3, right half): how each
//! quantizer changes the outlier structure of the activation stream,
//! and the correlation with downstream quality the paper reports.
//!
//! Run after `make artifacts`:
//! `cargo run --release --example outlier_analysis`

use bpdq::data::{CorpusConfig, CorpusGen, Split, Tokenizer};
use bpdq::eval::{outliers::activation_outliers, perplexity};
use bpdq::io::tlm::TlmFile;
use bpdq::model::pipeline::quantize_model;
use bpdq::model::Model;
use bpdq::quant::{BpdqConfig, QuantMethod, UniformConfig, VqConfig};
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let ckpt = Path::new("artifacts/tiny_small.tlm");
    anyhow::ensure!(ckpt.exists(), "run `make artifacts` first");
    let model = Model::from_tlm(&TlmFile::load(ckpt)?)?;
    let gen = CorpusGen::new(CorpusConfig::default());
    let tok = Tokenizer::new();

    let probes: Vec<Vec<u32>> = gen
        .token_docs(Split::Eval, 24, &tok)
        .into_iter()
        .map(|mut d| {
            d.truncate(model.cfg.max_seq);
            d
        })
        .collect();
    let eval_docs = gen.token_docs(Split::Eval, 24, &tok);
    let calib: Vec<Vec<u32>> = gen
        .token_docs(Split::Calib, 48, &tok)
        .into_iter()
        .map(|mut d| {
            d.truncate(model.cfg.max_seq);
            d
        })
        .filter(|d| d.len() >= 8)
        .collect();

    let base = activation_outliers(&model, &probes);
    println!(
        "{:<16} {:>9} {:>9} {:>7} {:>8} {:>9}",
        "model", "DiagR-P95", "ΔDiagR", "Cnt10", "ΔCnt10", "ppl"
    );
    println!(
        "{:<16} {:>9.2} {:>9} {:>7} {:>8} {:>9.3}",
        "FP16",
        base.diag_r_p95,
        "-",
        base.cnt10,
        "-",
        perplexity(&model, &eval_docs)
    );

    for (name, method) in [
        (
            "GPTQ-W2-G32",
            QuantMethod::Gptq(UniformConfig { bits: 2, group_size: 32, act_order: true }),
        ),
        ("VPTQ-W2", QuantMethod::Vptq(VqConfig { bits: 2, ..Default::default() })),
        (
            "BPDQ-W2-G64",
            QuantMethod::Bpdq(BpdqConfig { k: 2, group_size: 64, ..Default::default() }),
        ),
    ] {
        eprintln!("quantizing {name}…");
        let qm = quantize_model(&model, &calib, &method)?;
        let s = activation_outliers(&qm.model, &probes);
        let (dr, dc) = s.delta_vs(&base);
        println!(
            "{:<16} {:>9.2} {:>+8.1}% {:>7} {:>+7.1}% {:>9.3}",
            name,
            s.diag_r_p95,
            dr * 100.0,
            s.cnt10,
            dc * 100.0,
            perplexity(&qm.model, &eval_docs)
        );
    }
    println!("\n(paper shape: outlier preservation — small |Δ| — tracks lower ppl;");
    println!(" GPTQ-W2 suppresses outliers hardest and pays for it)");
    Ok(())
}
