//! Quickstart: quantize one linear layer with every method and compare
//! the output-aligned error — the 30-second tour of the library.
//!
//! Run: `cargo run --release --example quickstart`
//!
//! From here, the 60-second tour of the serving stack — a real HTTP/SSE
//! endpoint over the quantized engine, and a wire-level load test:
//!
//! ```text
//! # terminal 1: quantize W2-G256, serve over HTTP/SSE (+ raw BPQ1)
//! cargo run --release -- serve --listen 127.0.0.1:8090 \
//!     --engine lut --kv-bits 2 --prefix-cache
//!
//! # terminal 2: stream tokens with any HTTP client …
//! curl -N -X POST http://127.0.0.1:8090/v1/generate \
//!     -H 'Content-Type: application/json' \
//!     -d '{"prompt":"17+25=","max_new":8}'
//!
//! # … or replay Zipf traffic and measure goodput/TTFT/ITL on the wire
//! cargo run --release -- loadgen --addr 127.0.0.1:8090 \
//!     --requests 64 --concurrency 8 --drain
//! ```

use bpdq::quant::{
    quantize_linear, BcqConfig, BpdqConfig, QuantMethod, UniformConfig, VqConfig,
};
use bpdq::rng::Rng;
use bpdq::tensor::Matrix;

fn main() -> anyhow::Result<()> {
    // A heavy-tailed weight matrix with Zipf-skewed calibration
    // activations — the statistics real LLM layers show.
    let (d_out, d_in, n_samples) = (64, 256, 192);
    let mut rng = Rng::new(0xB9D9);
    let w = Matrix::from_vec(
        d_out,
        d_in,
        (0..d_out * d_in).map(|_| 0.1 * rng.student_t(5.0) as f32).collect(),
    );
    let x = Matrix::from_vec(
        n_samples,
        d_in,
        (0..n_samples * d_in)
            .map(|i| {
                let ch = i % d_in;
                let scale = (1.0 / (1.0 + ch as f64)).sqrt() as f32 * 3.0 + 0.05;
                scale * rng.normal() as f32
            })
            .collect(),
    );

    println!("quantizing a {d_out}×{d_in} layer at 2-bit with every method:\n");
    println!("{:<16} {:>6}  {:>14}  {:>12}", "method", "BPW", "‖(W−Ŵ)X‖²_F", "time");
    let uc = UniformConfig { bits: 2, group_size: 32, act_order: true };
    let methods = [
        QuantMethod::Rtn(uc),
        QuantMethod::Awq(uc),
        QuantMethod::Gptq(uc),
        QuantMethod::AnyBcq(BcqConfig { bits: 2, group_size: 64, alt_iters: 6 }),
        QuantMethod::Vptq(VqConfig::default()),
        QuantMethod::Bpdq(BpdqConfig { k: 2, group_size: 64, ..Default::default() }),
    ];
    for m in methods {
        let q = quantize_linear(&w, &x, m)?;
        println!(
            "{:<16} {:>6.2}  {:>14.4}  {:>9.1} ms",
            q.method,
            q.bits_per_weight(),
            q.stats.output_err,
            q.stats.secs * 1e3
        );
    }
    println!("\nExpected ordering (the paper's Figure 1b): VPTQ ≲ BPDQ < AnyBCQ/GPTQ ≪ AWQ/RTN.");
    Ok(())
}
